"""Flush-path data movement: the copy-on-write leaf-snapshot contract,
the device-resident leaf cache (keying, eviction, donation interaction,
capture/async-flush replays), and the jitted uint32-pair 64-bit
evaluator's divmod under adversarial operands.

The invariant under test throughout: outputs and ``EngineStats`` are
bit-identical with the leaf cache on, off, or disabled — the cache and
the snapshot elision are execution details, never semantics knobs.
"""

import numpy as np
import pytest

import repro.pum as pum
from repro.kernels.fused_program import (FusedOp, FusedProgram,
                                         run_program_pairs,
                                         run_program_words)
from repro.kernels.plane_layout import LAYOUT64

pytestmark = pytest.mark.fused


# --------------------------------------------------------------------- #
# Copy-on-write fingerprint contract
# --------------------------------------------------------------------- #


def test_inplace_mutation_between_recorded_uses_registers_fresh_leaf():
    """The engine's leaf guarantee: mutating an array in place between
    two recorded uses re-registers it as a *fresh* leaf — each use sees
    the content at its own registration time, at any array size."""
    dev = pum.device(width=32, fuse=True)
    rng = np.random.default_rng(5)
    mod = np.uint64(1) << np.uint64(32)
    for n in (17, 256, 100_000):
        a = rng.integers(0, 2**32, n, dtype=np.uint64)
        before = a.copy()
        y = dev.asarray(a) + 1
        # Mutate at a fingerprint-sampled index (the contract's domain;
        # unsampled-index mutation of shared arrays is the documented
        # 257-sample hole).
        idx = np.linspace(0, n - 1, min(n, 257)).astype(np.int64)[-2]
        a[idx] ^= np.uint64(0x5A5A)
        z = dev.asarray(a) + 1
        np.testing.assert_array_equal(y.to_numpy(), (before + 1) % mod)
        np.testing.assert_array_equal(z.to_numpy(), (a + 1) % mod)
    dev.close()


def test_pointer_reuse_with_new_content_misses_and_replaces():
    """A reused allocation with new content must not serve the stale
    cached upload: the fingerprint mismatch misses and replaces."""
    dev = pum.device(width=32, fuse=True)
    a = np.arange(4096, dtype=np.uint64)
    r1 = (dev.asarray(a) ^ 3).to_numpy()
    np.testing.assert_array_equal(r1, np.arange(4096, dtype=np.uint64) ^ 3)
    a[:] = a[::-1]  # same buffer, same pointer, new bytes
    r2 = (dev.asarray(a) ^ 3).to_numpy()
    np.testing.assert_array_equal(r2, a ^ 3)
    dev.close()


# --------------------------------------------------------------------- #
# Cache on/off identity
# --------------------------------------------------------------------- #


def _mixed_program(dev, a, b):
    x, y = dev.asarray(a), dev.asarray(b)
    t = (x + y) * x
    t = t - y
    t = t // (y + 1)
    t = t ^ x
    return t.to_numpy()


def test_outputs_and_stats_identical_with_cache_on_off():
    outs, stats = [], []
    for lcb in (1 << 26, 0, None):
        dev = pum.device(width=16, fuse=True, leaf_cache_bytes=lcb)
        rng = np.random.default_rng(11)
        a = rng.integers(0, 1 << 16, 3000, dtype=np.uint64)
        b = rng.integers(0, 1 << 16, 3000, dtype=np.uint64)
        got = [_mixed_program(dev, a, b) for _ in range(3)]
        assert all(np.array_equal(got[0], g) for g in got[1:])
        outs.append(got[0])
        stats.append(dev.stats)
        dev.close()
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])
    assert stats[0] == stats[1] == stats[2]


def test_leaf_cache_bytes_validation_and_disable():
    with pytest.raises(ValueError, match="leaf_cache_bytes"):
        pum.EngineConfig(leaf_cache_bytes=-1)
    assert pum.device(width=8, fuse=True,
                      leaf_cache_bytes=0).engine._leaf_cache is None
    assert pum.device(width=8, fuse=True,
                      leaf_cache_bytes=None).engine._leaf_cache is None


# --------------------------------------------------------------------- #
# Replay bit-exactness: flush_async and capture
# --------------------------------------------------------------------- #


def test_cache_hit_replays_bit_exact_across_flush_async_and_capture():
    dev = pum.device(width=16, fuse=True)
    n = 50_000
    a = (np.arange(n, dtype=np.uint64) * 7) % (1 << 16)
    b = (np.arange(n, dtype=np.uint64) * 13 + 5) % (1 << 16)
    mod = np.uint64(1) << np.uint64(16)

    prog = dev.capture(lambda x, y: (x + y) * x)
    want = ((a + b) % mod * a) % mod
    np.testing.assert_array_equal(prog(a, b), want)  # records + compiles
    for _ in range(3):  # replays: cache hits serve device buffers
        np.testing.assert_array_equal(prog(a, b), want)
    h = prog.call_async(a, b)
    np.testing.assert_array_equal(h.result(), want)

    # The same operands through ordinary flush_async on the device.
    for _ in range(2):
        x = dev.asarray(a) + dev.asarray(b)
        dev.flush_async().result()
        np.testing.assert_array_equal(x.to_numpy(), (a + b) % mod)
    dev.close()


# --------------------------------------------------------------------- #
# Donation-vs-cache interaction
# --------------------------------------------------------------------- #


def test_donation_never_serves_cached_device_buffers(monkeypatch):
    """Donated buffers are evicted, cached ones are never donated: a
    donating flush serves the private host wire (jax donates a fresh
    upload) and drops the entry's device residency — outputs and stats
    stay identical to the non-donating device."""
    import repro.kernels.fused_program as fp
    monkeypatch.setattr(fp, "_NP_CUTOFF_WIRE_OPS", 1 << 10)  # pin jitted
    rng = np.random.default_rng(9)
    a = rng.integers(0, 2**64, 65536, dtype=np.uint64)
    b = rng.integers(0, 2**64, 65536, dtype=np.uint64)

    def prog(dev):
        x = dev.asarray(a)
        t = (x & b) | (x ^ b)
        t = (t & b) ^ x
        return t.to_numpy()

    don = pum.device(width=32, fuse=True, donate_leaves=True)
    plain = pum.device(width=32, fuse=True)
    cold = [prog(d) for d in (don, plain)]
    warm = [prog(d) for d in (don, plain)]  # leaf-cache hits on both
    np.testing.assert_array_equal(cold[0], cold[1])
    np.testing.assert_array_equal(warm[0], warm[1])
    np.testing.assert_array_equal(cold[0], warm[0])
    assert don.stats == plain.stats

    dcache = don.engine._leaf_cache
    assert len(dcache) > 0
    assert all(e.dev is None for e in dcache._entries.values())
    # The non-donating jitted raw path commits device buffers on hits.
    pcache = plain.engine._leaf_cache
    assert any(e.dev is not None for e in pcache._entries.values())
    don.close()
    plain.close()


# --------------------------------------------------------------------- #
# Telemetry: counters + span args (tracer-gated)
# --------------------------------------------------------------------- #


def test_leaf_cache_counters_and_leaf_upload_span_args():
    dev = pum.device(width=32, fuse=True)
    rng = np.random.default_rng(3)
    a = rng.integers(0, 2**64, 8192, dtype=np.uint64)
    b = rng.integers(0, 2**64, 8192, dtype=np.uint64)
    with pum.profile(dev) as tr:
        (dev.asarray(a) & b).to_numpy()  # cold: stages + inserts
        (dev.asarray(a) & b).to_numpy()  # warm: pointer+fp hits
    assert dev.counters["engine.leaf_cache.misses"] >= 2
    assert dev.counters["engine.leaf_cache.hits"] >= 2
    assert dev.counters["engine.leaf_bytes_staged"] > 0
    assert dev.counters["engine.snapshot_bytes_elided"] > 0
    ups = [args for (name, _, _, args) in tr.events
           if name == "flush.leaf_upload"]
    assert len(ups) >= 2
    assert all("bytes_staged" in u and "bytes_skipped" in u for u in ups)
    assert any(u["bytes_skipped"] > 0 for u in ups)  # the warm flush
    dev.close()


def test_untraced_flushes_record_no_counters():
    dev = pum.device(width=32, fuse=True)
    a = np.arange(1024, dtype=np.uint64)
    for _ in range(2):
        (dev.asarray(a) + 1).to_numpy()
    assert dev.counters.get("engine.leaf_cache.hits") == 0
    assert dev.counters.get("engine.leaf_bytes_staged") == 0
    dev.close()


# --------------------------------------------------------------------- #
# Eviction and the byte budget
# --------------------------------------------------------------------- #


def test_lru_eviction_respects_byte_budget():
    dev = pum.device(width=32, fuse=True, leaf_cache_bytes=8192)
    cache = dev.engine._leaf_cache
    arrs = [np.arange(512, dtype=np.uint64) + i for i in range(6)]
    with pum.profile(dev):
        for a in arrs:  # 2 KiB of wire each: 6 leaves overflow 8 KiB
            (dev.asarray(a) + 1).to_numpy()
    assert dev.counters["engine.leaf_cache.evictions"] >= 1
    assert cache.nbytes <= 8192
    assert 1 <= len(cache) <= 4
    dev.close()


def test_oversized_leaf_is_not_cached():
    dev = pum.device(width=32, fuse=True, leaf_cache_bytes=1024)
    a = np.arange(4096, dtype=np.uint64)  # 16 KiB of wire > budget
    r = (dev.asarray(a) + 1).to_numpy()
    np.testing.assert_array_equal(r, a + 1)
    assert len(dev.engine._leaf_cache) == 0
    dev.close()


# --------------------------------------------------------------------- #
# The jitted uint32-pair evaluator: adversarial divmod
# --------------------------------------------------------------------- #


def _stratified(rng, n, width):
    """Operands whose bit-length is uniform in [0, width] — exercises
    every normalization shift of the Knuth division."""
    bits = rng.integers(0, width + 1, n)
    v = rng.integers(0, 1 << 64, n, dtype=np.uint64)
    shift = (np.uint64(64) - np.maximum(bits, 1).astype(np.uint64))
    out = np.where(bits == 0, np.uint64(0), v >> shift).astype(np.uint64)
    mask = np.uint64((1 << width) - 1 if width < 64 else (1 << 64) - 1)
    return out & mask


@pytest.mark.parametrize("width", [64, 48, 33])
def test_run_program_pairs_divmod_adversarial(width):
    rng = np.random.default_rng(7)
    n = 8192
    a = _stratified(rng, n, width)
    b = _stratified(rng, n, width)
    b[::97] = 0  # zero divisors yield 0 (the unsigned NumPy semantics)
    if width == 64:  # Knuth-hard seeds: dense dividend, near-power divisor
        a[:4] = np.array([0x7FFF800100000000, 0x8000000000000000,
                          (1 << 64) - 1, 0x0001FFFFFFFFFFFF], np.uint64)
        b[:4] = np.array([0x800000000001, 0x100000001, 0xFFFFFFFF,
                          0x0000FFFFFFFF0001], np.uint64)
    prog = FusedProgram(
        width=width, n_inputs=2,
        ops=(FusedOp("divmod", (0, 1)), FusedOp("fst", (2,)),
             FusedOp("snd", (2,)), FusedOp("mul", (3, 1)),
             FusedOp("add", (5, 4))),
        outputs=(3, 4, 6), layout=LAYOUT64)
    wires = [LAYOUT64.to_wire(x) for x in (a, b)]
    got = [LAYOUT64.from_wire(np.asarray(o))
           for o in run_program_pairs(prog, wires)]
    want = run_program_words(prog, [LAYOUT64.from_wire(w) for w in wires])
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, np.asarray(w, dtype=g.dtype))
