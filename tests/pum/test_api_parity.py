"""Operator-overload parity: every PumArray dunder against the NumPy
oracle, across widths 8/16/32 and eager vs fused devices — including
``__divmod__``, division by zero, reflected operands and scalar
broadcast. The cost plane must charge identically in both modes."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

import repro.pum as pum

pytestmark = pytest.mark.fused

WIDTHS = [8, 16, 32]


def _operands(width, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << width, n, dtype=np.uint64)
    b = rng.integers(0, 1 << width, n, dtype=np.uint64)
    # Edge lanes: zeros, ones, the signed boundary, the max value, and
    # div-by-zero divisors.
    edges = np.array([0, 1, 1 << (width - 1), (1 << width) - 1], np.uint64)
    a[:4], b[:4] = edges, edges[::-1]
    b[::5] = 0
    return a, b


def _mask(width):
    return np.uint64((1 << width) - 1)


def _oracles(width, a, b):
    m = _mask(width)
    with np.errstate(divide="ignore", invalid="ignore"):
        return {
            "and": a & b, "or": a | b, "xor": a ^ b,
            "add": (a + b) & m, "sub": (a - b) & m, "mul": (a * b) & m,
            "div": a // np.where(b == 0, 1, b) * (b != 0),
            "mod": a % np.where(b == 0, 1, b) * (b != 0),
            "lt": (a < b).astype(np.uint64),
            "gt": (b < a).astype(np.uint64),
            "le": (a <= b).astype(np.uint64),
            "ge": (a >= b).astype(np.uint64),
            "popcount": np.array([bin(int(x)).count("1") for x in a],
                                 np.uint64),
            "reduce_and": (a == m).astype(np.uint64),
            "reduce_or": (a != 0).astype(np.uint64),
            "reduce_xor": np.array([bin(int(x)).count("1") & 1 for x in a],
                                   np.uint64),
        }


def _results(dev, a, b):
    x, y = dev.asarray(a), dev.asarray(b)
    q, r = divmod(x, y)
    out = {
        "and": x & y, "or": x | y, "xor": x ^ y,
        "add": x + y, "sub": x - y, "mul": x * y,
        "div": x // y, "mod": x % y,
        "divmod_q": q, "divmod_r": r,
        "lt": x < y, "gt": x > y,
        "le": x <= y, "ge": x >= y,
        "popcount": x.popcount(),
        "reduce_and": x.reduce_bits("and"),
        "reduce_or": x.reduce_bits("or"),
        "reduce_xor": x.reduce_bits("xor"),
    }
    return {k: np.asarray(v, np.uint64) for k, v in out.items()}


@given(width=st.sampled_from(WIDTHS), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_every_dunder_matches_numpy_eager_vs_fused(width, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(33, 300))  # deliberately not a multiple of 32
    a, b = _operands(width, max(n, 20), seed)
    want = _oracles(width, a, b)
    eager = pum.device(width=width, fuse=False)
    fused = pum.device(width=width, fuse=True)
    got_e, got_f = _results(eager, a, b), _results(fused, a, b)
    for k, w in want.items():
        np.testing.assert_array_equal(got_e[k], w, err_msg=f"eager {k}")
        np.testing.assert_array_equal(got_f[k], w, err_msg=f"fused {k}")
    # divmod == (div, mod), one restoring-division pass
    for g in (got_e, got_f):
        np.testing.assert_array_equal(g["divmod_q"], want["div"])
        np.testing.assert_array_equal(g["divmod_r"], want["mod"])
    assert eager.stats == fused.stats


@pytest.mark.parametrize("width", WIDTHS)
@pytest.mark.parametrize("fuse", [False, True])
def test_divmod_divide_by_zero_yields_zero(width, fuse):
    dev = pum.device(width=width, fuse=fuse)
    a = np.array([7, 0, (1 << width) - 1], np.uint64)
    z = np.zeros(3, np.uint64)
    q, r = divmod(dev.asarray(a), z)
    np.testing.assert_array_equal(np.asarray(q), z)
    np.testing.assert_array_equal(np.asarray(r), z)


@pytest.mark.parametrize("fuse", [False, True])
def test_reflected_operators_with_ndarray_left(fuse):
    """ndarray OP PumArray must come back through the reflected dunders
    (NumPy yields to us via __array_ufunc__ = None), not element-wise."""
    dev = pum.device(width=16, fuse=fuse)
    a = np.array([100, 40, 7], np.uint64)
    p = dev.asarray(np.array([9, 40, 50], np.uint64))
    cases = {
        "and": (a & p, a & np.asarray(p)),
        "or": (a | p, a | np.asarray(p)),
        "xor": (a ^ p, a ^ np.asarray(p)),
        "add": (a + p, a + np.asarray(p)),
        "sub": (a - p, (a - np.asarray(p)) & np.uint64(0xFFFF)),
        "mul": (a * p, a * np.asarray(p)),
        "div": (a // p, a // np.asarray(p)),
        "mod": (a % p, a % np.asarray(p)),
    }
    for k, (got, want) in cases.items():
        assert isinstance(got, pum.PumArray), k
        np.testing.assert_array_equal(np.asarray(got, np.uint64),
                                      want.astype(np.uint64), err_msg=k)
    q, r = divmod(a, p)
    np.testing.assert_array_equal(np.asarray(q), a // np.asarray(p))
    np.testing.assert_array_equal(np.asarray(r), a % np.asarray(p))
    # comparisons: a < p dispatches to PumArray.__gt__ and vice versa
    np.testing.assert_array_equal(np.asarray(a < p),
                                  (a < np.asarray(p)).astype(np.uint64))
    np.testing.assert_array_equal(np.asarray(p < a),
                                  (np.asarray(p) < a).astype(np.uint64))
    np.testing.assert_array_equal(np.asarray(a <= p),
                                  (a <= np.asarray(p)).astype(np.uint64))
    np.testing.assert_array_equal(np.asarray(a >= p),
                                  (a >= np.asarray(p)).astype(np.uint64))


@pytest.mark.parametrize("fuse", [False, True])
def test_scalar_operands_broadcast_and_stay_fusable(fuse):
    dev = pum.device(width=8, fuse=fuse)
    x = dev.asarray(np.array([3, 5, 250], np.uint64))
    y = (x + 6) * x
    np.testing.assert_array_equal(y.to_numpy(),
                                  np.array([27, 55, 0], np.uint64))


def test_eq_ne_follow_ndarray_value_semantics():
    dev = pum.device(width=16, fuse=True)
    z = np.arange(4, dtype=np.uint64)
    t1, t2 = dev.asarray(z) + z, dev.asarray(z) + z
    np.testing.assert_array_equal(t1 == t2, np.full(4, True))
    np.testing.assert_array_equal(t1 != t2, np.full(4, False))
    with pytest.raises(ValueError):  # ambiguous, exactly like ndarray
        bool(dev.asarray(z) + z)
    with pytest.raises(TypeError):
        hash(t1)


def test_raw_packed_bitmap_operators_bit_exact():
    """Plane-wise operators on full-range uint64 words route through the
    raw planewise path in fused mode — bit-exact with eager."""
    rng = np.random.default_rng(3)
    a = rng.integers(0, 2**64, 65, dtype=np.uint64)
    b = rng.integers(0, 2**64, 65, dtype=np.uint64)
    eager = pum.device(width=32, fuse=False)
    fused = pum.device(width=32, fuse=True)

    def chain(dev):
        t = dev.asarray(a) & b
        t = t ^ a
        return (t | b).to_numpy()

    got_e, got_f = chain(eager), chain(fused)
    np.testing.assert_array_equal(got_e, got_f)
    np.testing.assert_array_equal(got_f, ((a & b) ^ a) | b)
    assert eager.stats == fused.stats
    # arithmetic on out-of-width operands still fails loudly when fused
    with pytest.raises(ValueError, match="modulo"):
        fused.asarray(a) + b


def test_array_protocol_and_ndarray_conveniences():
    dev = pum.device(width=16, fuse=True)
    m = np.arange(12, dtype=np.uint64).reshape(3, 4)
    t = dev.asarray(m) + m
    assert t.shape == (3, 4) and t.size == 12 and t.ndim == 2
    assert t.dtype == np.uint64 and len(t) == 3
    assert "PumArray" in repr(t)
    np.testing.assert_array_equal(t.reshape(4, 3), (2 * m).reshape(4, 3))
    assert t.sum() == 2 * m.sum()
    assert t.astype(np.int32).dtype == np.int32
    np.testing.assert_array_equal(np.asarray(t, np.float64),
                                  (2 * m).astype(np.float64))
    np.testing.assert_array_equal(t.to_numpy(), 2 * m)
