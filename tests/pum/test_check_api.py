"""The CI API-snapshot checker must pass against the current tree (and
actually detect drift)."""

import importlib.util
import pathlib

TOOLS = pathlib.Path(__file__).resolve().parents[2] / "tools"


def _load_check_api():
    spec = importlib.util.spec_from_file_location(
        "check_api", TOOLS / "check_api.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_public_surface_matches_snapshot(capsys):
    mod = _load_check_api()
    assert mod.main([]) == 0
    assert "surface OK" in capsys.readouterr().out


def test_snapshot_detects_drift(capsys):
    mod = _load_check_api()
    mod.EXPECTED["PumArray"] = mod.EXPECTED["PumArray"] + ["__matmul__"]
    assert mod.main([]) == 1
    assert "missing exports" in capsys.readouterr().err
