"""End-to-end LM training driver (deliverable b: the train-~100M example).

  PYTHONPATH=src python examples/train_lm.py                  # ~25M, fast
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Uses the full production stack: config, synthetic data pipeline with
prefetch, jit'd train step (donation, clipping, schedule), async sharded
checkpointing with resume, heartbeat monitor. Kill and rerun with the same
--ckpt-dir to see fault-tolerant resume.
"""

import argparse
import dataclasses

from repro.checkpoint.manager import CheckpointManager
from repro.config.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.train.trainer import TrainLoopHooks, train_loop

PRESETS = {
    # ~25M params: minutes on CPU.
    "25m": ModelConfig(name="demo-25m", family="dense", n_layers=8,
                       d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
                       d_ff=1152, vocab_size=4096, vocab_pad_multiple=128,
                       remat="none"),
    # ~100M params (the deliverable-scale run; slower per step on CPU).
    "100m": ModelConfig(name="demo-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                        d_ff=2304, vocab_size=8192, vocab_pad_multiple=128,
                        remat="none"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="25m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")
    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=30,
                       total_steps=args.steps, checkpoint_every=100)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = (ckpt.latest_step() or 0) if ckpt else 0
    data = Prefetcher(SyntheticLM(DataConfig(
        seq_len=args.seq_len, global_batch=args.batch,
        vocab_size=cfg.vocab_size)), start_step=start)
    monitor = HeartbeatMonitor()

    def on_step(step, metrics, dt):
        monitor.beat("w0", dt)
        if step % 10 == 0 or step == args.steps - 1:
            toks = args.batch * args.seq_len / dt
            print(f"step {step:4d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.2f} {toks:,.0f} tok/s",
                  flush=True)

    try:
        _, _, hist = train_loop(cfg, tcfg, data, args.steps, checkpoint=ckpt,
                                hooks=TrainLoopHooks(on_step=on_step))
    finally:
        data.close()
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"({len(hist)} steps run)")


if __name__ == "__main__":
    main()
