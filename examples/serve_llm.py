"""Batched LM serving with continuous batching (deliverable b).

  PYTHONPATH=src python examples/serve_llm.py --arch qwen1.5-0.5b

Spins up the slot-based serving engine on a reduced config, submits a burst
of requests, and reports TTFT / throughput. The same prefill/decode step
functions are what the multi-pod dry-run lowers at 256/512-chip scale.
"""

import argparse
import time

import numpy as np

from repro.config.base import ARCH_IDS, get_smoke_config
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    eng = ServeEngine(cfg, max_batch=4, max_len=128, eos_id=-1)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, 16, dtype=np.int32),
            max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    ttfts = [r.t_first - r.t_submit for r in done]
    print(f"{len(done)} requests, {total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens/wall:.1f} tok/s aggregate)")
    print(f"TTFT: mean {np.mean(ttfts)*1e3:.0f} ms  "
          f"p max {np.max(ttfts)*1e3:.0f} ms")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
