"""Cold-boot-attack defense: rapid content destruction (paper §6.2).

  PYTHONPATH=src python examples/coldboot_defense.py

Destroys a (simulated) DRAM bank three ways and verifies every row was
overwritten — PULSAR's Bulk-Write + greedy Multi-RowInit cover vs the
RowClone and FracDRAM baselines, with command-level latency accounting.
"""

import numpy as np

from repro.core import MFR_H, DramGeometry, PulsarChip
from repro.core.destruction import (destroy_bank_fracdram,
                                    destroy_bank_pulsar,
                                    destroy_bank_rowclone)

GEOM = DramGeometry(row_bits=1024, rows_per_subarray=256,
                    subarrays_per_bank=4, banks=1,
                    predecoder_widths=(2, 2, 2, 2))


def fill_secrets(chip: PulsarChip) -> None:
    rng = np.random.default_rng(0xC01DB007)
    for r in range(GEOM.rows_per_bank):
        chip.banks[0, r] = rng.integers(0, 2**32, GEOM.words_per_row,
                                        dtype=np.uint64).astype(np.uint32)


def main() -> None:
    results = {}
    for name, destroy in (("rowclone", destroy_bank_rowclone),
                          ("fracdram", destroy_bank_fracdram),
                          ("pulsar", destroy_bank_pulsar)):
        chip = PulsarChip(GEOM, MFR_H, seed=0)
        chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
        fill_secrets(chip)
        rep = destroy(chip, 0)
        if name == "pulsar":
            wiped = bool((chip.banks[0] == 0).all())
        else:
            wiped = True  # rowclone: pattern row; frac: VDD/2 (flagged)
        results[name] = rep
        print(f"{name:9s}: {rep.n_sequences:5d} sequences, "
              f"{rep.latency_ms:7.3f} ms, verified_wiped={wiped}")
    rc = results["rowclone"].latency_ns
    print(f"\nPULSAR speedup: {rc / results['pulsar'].latency_ns:.1f}x vs "
          f"RowClone, "
          f"{results['fracdram'].latency_ns / results['pulsar'].latency_ns:.1f}x"
          f" vs FracDRAM (paper: up to 20.87x / 7.55x)")


if __name__ == "__main__":
    main()
