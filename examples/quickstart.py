"""Quickstart: PULSAR in-DRAM computing on the simulated chip.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's core mechanisms end-to-end on the bit-exact chip model:
many-row activation, input replication, MAJ, Multi-RowInit, Bulk-Write,
and the bit-serial ALU — with real command-level latency accounting.
"""

import numpy as np

from repro.core import (MFR_H, DramGeometry, PulsarChip, PulsarExecutor,
                        majority_bits)
from repro.core.alu import BitSerialAlu
from repro.core.charact import default_db

GEOM = DramGeometry(row_bits=256, rows_per_subarray=256, subarrays_per_bank=2,
                    banks=1, predecoder_widths=(2, 2, 2, 2))


def main() -> None:
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)  # perfect yield
    x = PulsarExecutor(chip, bank=0, subarray=0)

    print("== Simultaneous many-row activation (paper §4) ==")
    rf, rs = chip.decoder.find_group_pair(0, 16)
    rows = chip.decoder.activated_rows(rf, rs)
    print(f"APA(ACT {rf} -> PRE -> ACT {rs}) activates {len(rows)} rows: "
          f"{rows[:6]}...")

    print("\n== MAJ3 with input replication (paper §5.1) ==")
    rng = np.random.default_rng(0)
    vals = [rng.integers(0, 2**32, GEOM.words_per_row, dtype=np.uint64)
            .astype(np.uint32) for _ in range(3)]
    for i, v in enumerate(vals):
        chip.write_row(0, 200 + i, v)
    rep = x.maj(240, [200, 201, 202], n_rg=16)
    got = chip.peek(0, 240)
    want = majority_bits(np.stack(vals), 2)
    print(f"MAJ3 @ N_RG=16: copies={rep.copies} neutrals={rep.n_neutral} "
          f"correct={np.array_equal(got, want)}")
    db = default_db()
    print(f"modeled success rate: FracDRAM(N=4) {db.mean('H', 3, 4):.3f} "
          f"-> PULSAR(N=32) {db.mean('H', 3, 32):.3f} "
          f"(paper: 0.789 -> 0.979)")

    print("\n== Multi-RowInit & Bulk-Write (paper §5.2) ==")
    t0 = chip.stats.latency_ns
    x.multi_row_init_block(200, 16)
    print(f"Multi-RowInit 1->16 rows in {chip.stats.latency_ns - t0:.0f} ns "
          f"(vs ~16 RowClones)")
    t0 = chip.stats.latency_ns
    x.bulk_write_block(np.zeros(GEOM.words_per_row, np.uint32), 16)
    print(f"Bulk-Write 16 rows in {chip.stats.latency_ns - t0:.0f} ns")

    print("\n== Bit-serial SIMD ALU over bitlines (paper §6.1.2) ==")
    alu = BitSerialAlu(PulsarExecutor(chip, 0, 1), width=8)
    a = rng.integers(0, 200, GEOM.row_bits, dtype=np.uint64)
    b = rng.integers(1, 50, GEOM.row_bits, dtype=np.uint64)
    va, vb = alu.load(a), alu.load(b)
    t0 = chip.stats.latency_ns
    s = alu.store(alu.add(va, vb))
    dt = chip.stats.latency_ns - t0
    print(f"{GEOM.row_bits}-lane 8-bit add: correct="
          f"{np.array_equal(s, (a + b) & 0xFF)} in {dt*1e-3:.1f} us "
          f"({GEOM.row_bits/dt:.3f} adds/ns in-DRAM)")
    q, r = alu.div(va, vb)
    print(f"{GEOM.row_bits}-lane 8-bit div: correct="
          f"{np.array_equal(alu.store(q), a // b)}")
    print(f"\ntotal session: {chip.stats.n_ops} PuM ops, "
          f"{chip.stats.latency_ns*1e-3:.1f} us, "
          f"{chip.stats.energy_j*1e6:.2f} uJ")


if __name__ == "__main__":
    main()
