"""PuM-accelerated database analytics (paper Appendix B use case).

  PYTHONPATH=src python examples/pum_database.py

Runs the paper's two database workloads on the PULSAR engine through the
public ``repro.pum`` API:
  * BMI   — bitmap-index query "users active every day this month",
  * BW    — BitWeaving predicate scan count(*) where c1 <= v <= c2,
plus the graph set-intersection (triangle counting) — with PuM latency from
the calibrated cost model vs this host's NumPy time for context.
"""

import numpy as np

import repro.pum as pum
from repro.core import realworld


def main() -> None:
    rng = np.random.default_rng(7)
    # fuse=True is the EngineConfig default: op chains record into one
    # fused program per materialization; results and cost-plane numbers
    # are identical to eager mode. The `with` scope auto-flushes on exit.
    with pum.device(mfr="M", width=32, banks=16) as dev:
        print("== Bitmap index (BMI): daily-active-users query ==")
        n_users = 8_000_000
        days = 30
        bitmaps = rng.integers(0, 2**63, (days, n_users // 64),
                               dtype=np.uint64)
        count, pum_ms, cpu_ms = realworld.bmi_active_users(dev, bitmaps)
        print(f"{n_users:,} users x {days} days -> {count:,} always-active")
        print(f"PuM {pum_ms:.2f} ms (16 banks) | host numpy {cpu_ms:.2f} ms")

        print("\n== BitWeaving scan: count(*) where 10_000 <= v <= 60_000 ==")
        col = rng.integers(0, 100_000, 1_000_000, dtype=np.uint64)
        count, pum_ms, cpu_ms = realworld.bitweaving_scan(dev, col,
                                                          10_000, 60_000)
        print(f"1M-row column -> {count:,} matches")
        print(f"PuM {pum_ms:.2f} ms | host numpy {cpu_ms:.2f} ms")

        print("\n== Triangle counting (set-centric AND + popcount) ==")
        n = 96
        adj = np.triu((rng.random((n, n)) < 0.15).astype(np.uint8), 1)
        tri, pum_ms, cpu_ms = realworld.triangle_count(dev, adj + adj.T)
        print(f"{n}-vertex graph -> {tri} triangles")
        print(f"PuM {pum_ms:.2f} ms | host numpy {cpu_ms:.2f} ms")

        st = dev.stats
        print(f"\ndevice session: {st.n_sequences:,} row-activation "
              f"sequences, stable-lane efficiency {st.lane_efficiency:.3f}")


if __name__ == "__main__":
    main()
