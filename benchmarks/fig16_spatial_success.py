"""Fig 16: spatial distribution of MAJ3 success across a bank's subarrays
(M-shaped systematic-variation profile; PULSAR beats FracDRAM in every
subarray — paper: +66.23% average)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.core.charact import SuccessRateDb


def run() -> list[Row]:
    db = SuccessRateDb(n_bitlines=512, n_groups=4, n_patterns=24)
    us, table = timed_us(lambda: db.fig16_spatial("H", n_subarrays=8),
                         repeat=1)
    pulsar = np.array([t[1] for t in table])
    frac = np.array([t[2] for t in table])
    gain = (pulsar.mean() / max(frac.mean(), 1e-9) - 1) * 100
    better_everywhere = bool((pulsar >= frac).all())
    # M-shape (visible on the unsaturated FracDRAM curve): success dips at
    # the quarter positions relative to the edges.
    m_shape = bool(frac[2] < frac[0] and frac[5] < frac[7])
    return [row("fig16.spatial", us,
                f"pulsar_mean={pulsar.mean():.3f} frac_mean={frac.mean():.3f} "
                f"gain={gain:.0f}% (paper +66.23%) "
                f"everywhere_better={better_everywhere} m_shape={m_shape}")]
