"""Fig 14: MAJ3 success rate vs N_RG per manufacturer (PULSAR headline:
97.91% at N=32 vs FracDRAM 78.85% — +24.18 points)."""

from __future__ import annotations

from benchmarks.common import Row, row, timed_us
from repro.core.charact import SuccessRateDb

PAPER = {("H", 4): 0.7885, ("H", 32): 0.9791}


def run() -> list[Row]:
    db = SuccessRateDb(n_bitlines=1024, n_groups=6, n_patterns=32)
    rows: list[Row] = []
    for mfr, ns in (("H", (4, 8, 16, 32)), ("M", (4, 8, 16))):
        for n in ns:
            us, pt = timed_us(lambda m=mfr, nn=n: db.point(m, 3, nn),
                              repeat=1)
            ref = PAPER.get((mfr, n))
            rows.append(row(
                f"fig14.maj3_{mfr}_n{n}", us,
                f"sim={pt.mean:.4f} iqr=[{pt.q1:.3f},{pt.q3:.3f}]"
                + (f" paper={ref}" if ref else "")))
    h4 = db.mean("H", 3, 4)
    h32 = db.mean("H", 3, 32)
    rows.append(row("fig14.pulsar_vs_fracdram_gain", 0.0,
                    f"sim=+{100*(h32-h4):.1f}pts paper=+24.18pts"))
    return rows
