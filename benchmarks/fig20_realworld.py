"""Fig 20 (Appendix B): real-world kernels on the PuM engine — PULSAR vs
FracDRAM-configured engine vs this host's NumPy as the CPU reference.

Bank-level parallelism is priced through the MemoryController: PULSAR:16
uses all 16 banks, but the scheduled trace caps effective parallelism at
what tFAW/tRRD allow and adds the tREFI/tRFC refresh-interference stall
(reported per kernel as ``refresh=``; the paper's best configuration is
1.59x over FracDRAM:16 / 43x over CPU on their Skylake)."""

from __future__ import annotations

import numpy as np

import repro.pum as pum
from benchmarks.common import Row, row
from repro.controller import MemoryController
from repro.core import realworld


# One controller per tREFI, shared across devices/kernels: it is stateless
# across schedule() calls and its batch_cost cache makes repeat pricing free.
_CONTROLLERS: dict[float | None, MemoryController] = {}


def _devices(trefi: float | None = None):
    if trefi not in _CONTROLLERS:
        _CONTROLLERS[trefi] = MemoryController(n_banks=16, trefi=trefi)
    ctrl = _CONTROLLERS[trefi]
    # fuse=True (the EngineConfig default): the app kernels execute through
    # the fused dataplane (bit-exact, cost plane invariant — the reported
    # latencies are unchanged; the host-side dataplane just compiles to
    # fewer passes). One PULSAR device + one FracDRAM-configured twin.
    cfg = pum.EngineConfig(mfr="M", width=32, banks=16, controller=ctrl)
    return (pum.device(cfg), pum.device(cfg.replace(use_pulsar=False)))


def run() -> list[Row]:
    rng = np.random.default_rng(20)
    rows: list[Row] = []

    def emit(name, fn, *args, **kw):
        pul, frac = _devices()
        _, p_ms, cpu_ms = fn(pul, *args, **kw)
        _, f_ms, _ = fn(frac, *args, **kw)
        r_ms = pul.stats.refresh_stall_ns * 1e-6
        rows.append(row(
            f"fig20.{name}", p_ms * 1e3,
            f"pulsar={p_ms:.3f}ms frac={f_ms:.3f}ms host_numpy={cpu_ms:.3f}ms "
            f"pulsar_vs_frac={f_ms/max(p_ms,1e-9):.2f}x "
            f"refresh={r_ms:.4f}ms"))

    bitmaps = rng.integers(0, 2**63, (30, 1024), dtype=np.uint64)
    emit("bmi", realworld.bmi_active_users, bitmaps)
    col = rng.integers(0, 100000, 65536, dtype=np.uint64)
    emit("bitweaving", realworld.bitweaving_scan, col, 1000, 60000)
    n = 48
    adj = np.triu((rng.random((n, n)) < 0.25).astype(np.uint8), 1)
    emit("triangle_count", realworld.triangle_count, adj + adj.T)
    cl_adj = np.triu((rng.random((32, 32)) < 0.4).astype(np.uint8), 1)
    cl_adj = cl_adj + cl_adj.T
    cliques = [(0, 1, 2), (3, 4, 5), (6, 7, 8)]
    emit("kclique_star", realworld.kclique_star, cl_adj, cliques)
    q = rng.integers(0, 256, (8, 32), dtype=np.int64)
    r = rng.integers(0, 256, (256, 32), dtype=np.int64)
    emit("knn", realworld.knn_distances, q, r)
    img = rng.integers(0, 256, (64, 64), dtype=np.int64)
    emit("image_seg", realworld.image_segmentation, img,
         np.array([20, 90, 160, 230]))

    # XNOR-Net conv layers (op-count model): LeNet-5 + VGG-13-ish layer.
    pul, frac = _devices()
    for name, spec in {"xnor_lenet_c3": (6, 16, 5, 5, 10, 10),
                       "xnor_vgg_l5": (256, 256, 3, 3, 8, 8)}.items():
        p_ms = realworld.xnor_conv_cost(pul, *spec)
        f_ms = realworld.xnor_conv_cost(frac, *spec)
        rows.append(row(f"fig20.{name}", p_ms * 1e3,
                        f"pulsar={p_ms:.3f}ms frac={f_ms:.3f}ms "
                        f"ratio={f_ms/max(p_ms,1e-9):.2f}x "
                        f"refresh={pul.stats.refresh_stall_ns*1e-6:.4f}ms"))

    # Refresh interference is tREFI-dependent: halving tREFI (hot-temp 2x
    # refresh) roughly doubles the REF stall on the same kernel.
    for trefi in (7800.0, 3900.0):
        pul, _ = _devices(trefi=trefi)
        _, p_ms, _ = realworld.bmi_active_users(pul, bitmaps)
        rows.append(row(
            f"fig20.refresh_trefi{int(trefi)}", p_ms * 1e3,
            f"pulsar={p_ms:.3f}ms "
            f"refresh={pul.stats.refresh_stall_ns*1e-6:.4f}ms "
            f"trefi={trefi}ns"))
    return rows
