"""Reliability sweep: success rate + retry overhead vs injected variation.

One fused app-style program (the test suite's mixed logic/arith/compare
kernel, 512 lanes x 16 bits) runs against calibrated chips of decreasing
lot quality: process variation scaled up from the manufacturer nominal,
flip probabilities scaled by ``flip_scale`` (weak-lot model). Per point
the derived string reports the calibrated chip-wide success rate at the
flush config, fault/correction counts, retry + escalation overhead,
oracle fallbacks, and the bit-exactness flag (which must always be 1 —
the vote/retry loop degrades to the eager oracle rather than return a
wrong bit). The per-row telemetry counters ride along into
``BENCH_reliability.json``; ``tools/bench_compare.py --check-rows``
gates the row set in CI.

Steering is disabled in the injected rows so the sweep measures the
raw correction machinery (with steering on, this workload fits entirely
in the strong subarrays — that effect gets its own ablation row pair).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, record_counters, row, timed_us
from repro import pum
from repro.core.profiles import PROFILES

WIDTH = 16
LANES = 512
CAL = dict(n_subarrays=4, n_columns=64, n_patterns=4)
PV_NOMINAL = PROFILES["M"].process_variation


def _device(**kw):
    args = dict(mfr="M", width=WIDTH, banks=4, fuse=True, seed=7)
    args.update(kw)
    return pum.Device(**args)


def _workload(dev, a, b):
    x, y = dev.asarray(a), dev.asarray(b)
    out = (x & y) ^ (x + y)
    lt = x < y
    dev.flush()
    return out.to_numpy(), lt.to_numpy()


def _rel_counters(dev) -> dict:
    c = dev.counters.as_dict()["counters"]
    return {k.split(".", 1)[1]: v for k, v in c.items()
            if k.startswith("reliability.")}


def run() -> list[Row]:
    rng = np.random.default_rng(2026)
    a = rng.integers(0, 1 << WIDTH, LANES, np.uint64)
    b = rng.integers(0, 1 << WIDTH, LANES, np.uint64)

    rows: list[Row] = []

    # Clean fused reference: the eager-oracle values every other row is
    # checked against, plus the uninstrumented wall time.
    base_dev = _device()
    us, want = timed_us(_workload, base_dev, a, b)
    rows.append(row("rel.baseline", us,
                    f"lanes={LANES} width={WIDTH} inject=off map=off"))

    # Calibration pass cost (the one-time profile of the simulated chip).
    cal_dev = _device()
    us, rmap = timed_us(lambda: cal_dev.calibrate(attach=False, **CAL),
                        repeat=1)
    rows.append(row(
        "rel.calibrate", us,
        f"banks=4 subarrays={CAL['n_subarrays']} "
        f"columns={CAL['n_columns']} configs={len(rmap.configs)} "
        f"mean_success={np.mean(rmap.success):.4f}"))

    # Map attached, injection off: variation-aware planning only. Must be
    # bit-exact with zero reliability counters (the zero-overhead claim).
    plan_dev = _device()
    plan_dev.calibrate(process_variation=PV_NOMINAL * 3, **CAL)
    us, got = timed_us(_workload, plan_dev, a, b)
    exact = int(all(np.array_equal(g, w) for g, w in zip(got, want)))
    rows.append(row(
        "rel.plan_only", us,
        f"exact={exact} counters={len(_rel_counters(plan_dev))} "
        f"(map-guided fig11 replication, no injection)"))

    # Injection sweep: lot quality degrades left to right.
    for tag, pv_scale, flip_scale in (
            ("pv3_fs10", 3.0, 10.0),
            ("pv5_fs40", 5.0, 40.0),
            ("pv6_fs10", 6.0, 10.0)):
        dev = _device()
        dev.calibrate(inject=True, steer=False,
                      process_variation=PV_NOMINAL * pv_scale,
                      flip_scale=flip_scale, **CAL)
        m, n = dev.reliability._flush_config()
        success = dev.reliability.map.mean_success(m, n)
        us, got = timed_us(_workload, dev, a, b, repeat=1)
        exact = int(all(np.array_equal(g, w) for g, w in zip(got, want)))
        c = _rel_counters(dev)
        flushes = max(1, c.get("flushes", 0))
        name = f"rel.inject_{tag}"
        rows.append(row(
            name, us,
            f"exact={exact} success={success:.4f} "
            f"injected={c.get('injected_bits', 0)} "
            f"corrected={c.get('corrected_bits', 0)} "
            f"weak={c.get('weak_bits', 0)} "
            f"retries_per_flush={c.get('retries', 0) / flushes:.2f} "
            f"escalations={c.get('escalations', 0)} "
            f"fallbacks={c.get('oracle_fallbacks', 0)} "
            f"votes={c.get('votes_run', 0)}"))
        record_counters(name, dev.counters)

    # Steering ablation at the pv5/fs40 point: same chip, same workload,
    # map-guided placement on vs off.
    injected = {}
    for steer in (True, False):
        dev = _device()
        dev.calibrate(inject=True, steer=steer,
                      process_variation=PV_NOMINAL * 5, flip_scale=40.0,
                      **CAL)
        _workload(dev, a, b)
        injected[steer] = _rel_counters(dev).get("injected_bits", 0)
    rows.append(row(
        "rel.steer_ablation", 0.01,
        f"injected_steered={injected[True]} "
        f"injected_unsteered={injected[False]} "
        f"(weak-column steering avoids "
        f"{injected[False] - injected[True]} faults)"))
    return rows
