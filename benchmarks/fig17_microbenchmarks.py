"""Fig 17: the seven arithmetic/logic microbenchmarks — PULSAR (per-op
best-throughput config search) vs FracDRAM (MAJ3@4) per manufacturer.

Paper: 2.21x (Mfr M) / 1.46x (Mfr H) average speedup; our conservative
per-op staging model reproduces the structure (M > H, logic > arithmetic,
MAJ9 degradation) with smaller magnitudes — analysed in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.core.engine import PulsarEngine

KINDS = {
    "and": ("reduce_and", 64),
    "or": ("reduce_or", 64),
    "xor": ("reduce_xor", 64),
    "add": ("add", None),
    "sub": ("sub", None),
    "mul": ("mul", None),
    "div": ("div", None),
}

PAPER_AVG = {"M": 2.21, "H": 1.46}


def run() -> list[Row]:
    rows: list[Row] = []
    for mfr in ("M", "H"):
        pulsar = PulsarEngine(mfr=mfr, width=32, use_pulsar=True)
        chained = PulsarEngine(mfr=mfr, width=32, use_pulsar=True,
                               chained=True)
        frac = PulsarEngine(mfr=mfr, width=32, use_pulsar=False)
        speeds = {}

        def bench():
            for name, (kind, planes) in KINDS.items():
                m, n, sr_p, c_p = pulsar._cfg_for(kind, 32, planes)
                mc, nc, sr_c, c_c = chained._cfg_for(kind, 32, planes)
                _, _, sr_f, c_f = frac._cfg_for(kind, 32, planes)
                eff_f = c_f.latency_ns / sr_f
                speeds[name] = (eff_f / (c_p.latency_ns / sr_p),
                                eff_f / (c_c.latency_ns / sr_c), m, n)
            return speeds

        us, sp = timed_us(bench, repeat=1)
        for name, (s, sc, m, n) in sp.items():
            rows.append(row(f"fig17.{name}_{mfr}", us / 7,
                            f"speedup={s:.2f}x chained={sc:.2f}x "
                            f"cfg=MAJ{m}@N{n}"))
        avg = float(np.mean([s for s, _, _, _ in sp.values()]))
        avg_c = float(np.mean([sc for _, sc, _, _ in sp.values()]))
        rows.append(row(f"fig17.avg_{mfr}", us,
                        f"sim={avg:.2f}x chained={avg_c:.2f}x "
                        f"paper={PAPER_AVG[mfr]}x"))
    return rows
