"""Fig 17: the seven arithmetic/logic microbenchmarks — PULSAR (per-op
best-throughput config search) vs FracDRAM (MAJ3@4) per manufacturer.

Per-op latencies are priced through the MemoryController's scheduled
bank batches (16 banks: tFAW/tRRD-limited effective parallelism plus the
steady-state refresh factor), not the closed-form bank divide.

Paper: 2.21x (Mfr M) / 1.46x (Mfr H) average speedup; our conservative
per-op staging model reproduces the structure (M > H, logic > arithmetic,
MAJ9 degradation) with smaller magnitudes — analysed in EXPERIMENTS.md.

Units: the CSV's ``us_per_call`` column is *host* wall time of the
pricing pass (as in every benchmark module); the model-domain DRAM
latencies live in ``derived`` with explicit ``ns`` suffixes
(``pulsar=..ns frac=..ns``, success-rate-adjusted amortized per-row
latency from ``op_effective_ns``) alongside the dimensionless speedups.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.core.engine import PulsarEngine

KINDS = {
    "and": ("reduce_and", 64),
    "or": ("reduce_or", 64),
    "xor": ("reduce_xor", 64),
    "add": ("add", None),
    "sub": ("sub", None),
    "mul": ("mul", None),
    "div": ("div", None),
}

PAPER_AVG = {"M": 2.21, "H": 1.46}


def run() -> list[Row]:
    rows: list[Row] = []
    for mfr in ("M", "H"):
        pulsar = PulsarEngine(mfr=mfr, width=32, use_pulsar=True,
                              controller="auto")
        chained = PulsarEngine(mfr=mfr, width=32, use_pulsar=True,
                               chained=True, controller="auto")
        frac = PulsarEngine(mfr=mfr, width=32, use_pulsar=False,
                            controller="auto")
        speeds = {}

        def bench():
            for name, (kind, planes) in KINDS.items():
                l_p, sr_p, m, n = pulsar.op_effective_ns(kind, 32, planes)
                l_c, sr_c, _, _ = chained.op_effective_ns(kind, 32, planes)
                l_f, sr_f, _, _ = frac.op_effective_ns(kind, 32, planes)
                eff_f = l_f / sr_f
                eff_p = l_p / sr_p
                speeds[name] = (eff_f / eff_p, eff_f / (l_c / sr_c),
                                m, n, eff_p, eff_f)
            return speeds

        us, sp = timed_us(bench, repeat=1)
        for name, (s, sc, m, n, eff_p, eff_f) in sp.items():
            # Dimensionless speedups + the model-domain latencies behind
            # them, each with its unit spelled out (the us_per_call
            # column is host wall time of the pricing pass, NOT ns).
            rows.append(row(f"fig17.{name}_{mfr}", us / 7,
                            f"speedup={s:.2f}x chained={sc:.2f}x "
                            f"pulsar={eff_p:.1f}ns frac={eff_f:.1f}ns "
                            f"cfg=MAJ{m}@N{n}"))
        avg = float(np.mean([s for s, *_ in sp.values()]))
        avg_c = float(np.mean([sc for _, sc, *_ in sp.values()]))
        # Controller-derived bank scaling of the PULSAR add config: how much
        # of the 16-bank ideal survives tFAW/tRRD + refresh.
        b = pulsar._batch_for("add", *pulsar._cfg_for("add", 32, None)[:2])
        rows.append(row(
            f"fig17.avg_{mfr}", us,
            f"sim={avg:.2f}x chained={avg_c:.2f}x paper={PAPER_AVG[mfr]}x "
            f"bank_p_eff={b.parallel_speedup:.2f}/16 "
            f"refresh_factor={b.refresh_factor:.4f}"))
    return rows
