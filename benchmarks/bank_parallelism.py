"""Bank-parallelism sweep (§7): N MAJ ops spread over B banks, scheduled by
the MemoryController (bank machines + multiplexer + refresher) vs the same
command stream through the sequential CommandScheduler.

The speedup from overlapped issue is *measured from the scheduled trace*,
not assumed: tFAW/tRRD cap the activation rate, so effective parallelism
saturates well below the bank count (the honest version of the paper's
16-bank scaling), and REF injection shows up as a small extra stall.

The ``bankpar.refpost_p*`` rows sweep the refresher's REF postponing
policy (JEDEC allows batching up to 8 REFs into one rank lockout)
through ``MemoryController.batch_cost`` — the same cost-plane entry
point the engine prices through (``EngineConfig.ref_postponing``):
postponing trades lockout frequency for lockout length, so the
steady-state refresh factor shifts while the raw makespan is untouched.
"""

from __future__ import annotations

from benchmarks.common import Row, record_counters, row, timed_us
from repro.controller import MemoryController, retarget_program
from repro.core import commands as cmds
from repro.core.cost_model import CostModel
from repro.core.timing import DDR4_2400

N_OPS = 32
ROW_BITS = 65536


def run() -> list[Row]:
    t = DDR4_2400
    cm = CostModel(row_bits=ROW_BITS)
    unit = cm.maj_unit_programs(3, 8)   # one MAJ3@8 op (the Fig 17 staple)

    # Sequential baseline: the identical command stream through the legacy
    # scheduler (which serializes rank-wide regardless of bank tags).
    flat = [c for _ in range(N_OPS) for prog in unit for c in prog]
    seq_ns = cmds.CommandScheduler(t).schedule(flat).total_ns
    seq_thr = N_OPS * ROW_BITS / (seq_ns * 1e-9)

    rows: list[Row] = []
    rows.append(row("bankpar.sequential", seq_ns / 1e3,
                    f"total={seq_ns:.0f}ns maj_thr={seq_thr:.3e}elem/s "
                    f"(legacy CommandScheduler, {N_OPS} MAJ3@8 ops)"))

    for banks in (1, 2, 4, 8, 16):
        ctrl = MemoryController(n_banks=banks)
        programs = [retarget_program(prog, i % banks)
                    for i in range(N_OPS) for prog in unit]
        us, tr = timed_us(ctrl.schedule, programs, repeat=1)
        thr = N_OPS * ROW_BITS / (tr.total_ns * 1e-9)
        rows.append(row(
            f"bankpar.ctrl_b{banks}", us,
            f"total={tr.total_ns:.0f}ns maj_thr={thr:.3e}elem/s "
            f"speedup_vs_seq={seq_ns / tr.total_ns:.2f}x "
            f"refreshes={tr.n_refreshes} "
            f"refresh_stall={tr.refresh_stall_ns:.0f}ns"))
        # Post-hoc derived controller counters ride along in the BENCH
        # baseline (bus utilization, row hits, tRRD/tFAW stalls).
        record_counters(f"bankpar.ctrl_b{banks}", tr.counters())

    # 8 concurrent client streams through the crossbar: each port owns a
    # slice of the 16 banks, the multiplexer still enforces rank-wide
    # tFAW/tRRD — overlap is makespan vs the sum of per-stream serial
    # schedules, and the replayed audit trail must be violation-free.
    from repro.telemetry import check_timing_invariants
    n_ports = 8
    ctrl = MemoryController(n_banks=16)
    streams = [[retarget_program(prog, (i * n_ports + p) % 16)
                for i in range(N_OPS // n_ports) for prog in unit]
               for p in range(n_ports)]
    us, tr = timed_us(ctrl.schedule_concurrent, streams, repeat=1)
    serial_ns = sum(ctrl.schedule(s).total_ns for s in streams)
    viol = len(check_timing_invariants(tr))
    rows.append(row(
        "engine.crossbar_8client", us,
        f"makespan={tr.total_ns:.0f}ns serial_sum={serial_ns:.0f}ns "
        f"overlap={serial_ns / tr.total_ns:.2f}x "
        f"violations={viol} refreshes={tr.n_refreshes} "
        f"({n_ports} client ports, per-bank round-robin grants)"))
    record_counters("engine.crossbar_8client", tr.counters())

    # REF postponing sweep: batch_cost prices the same 16-bank MAJ unit
    # under each policy — refresh_factor is the steady-state slowdown the
    # engine multiplies into every op's latency.
    for post in (1, 2, 4, 8):
        ctrl = MemoryController(n_banks=16, postponing=post)
        us, bc = timed_us(ctrl.batch_cost, unit, 16, repeat=1)
        rows.append(row(
            f"bankpar.refpost_p{post}", us,
            f"refresh_factor={bc.refresh_factor:.4f} "
            f"amortized={bc.amortized_ns:.0f}ns "
            f"makespan={bc.makespan_ns:.0f}ns "
            f"refreshes={bc.n_refreshes} "
            f"lockout={ctrl.t.trp + ctrl.trfc * post:.0f}ns "
            f"(postponing={post} REFs per rank lockout)"))
    return rows
