"""Fig 18: sensitivity of MAJM performance to N_RG under the four scenarios:
RealExp (empirical SR + init latency), RealInit (SR=1, real init),
RealSR (real SR, no init), Ideal (SR=1, no init) — normalized to FracDRAM."""

from __future__ import annotations

from benchmarks.common import Row, row, timed_us
from repro.core.charact import SuccessRateDb
from repro.core.cost_model import CostModel
from repro.core.profiles import PROFILES


def scenario_latency(cm: CostModel, m: int, n: int, frac_supported: bool,
                     init: bool) -> float:
    full = cm.maj_op(m, n, frac_supported=frac_supported)
    if init:
        return full.latency_ns
    # no-init scenario: only the APA + copy-out remain.
    return (cm.apa() + cm.aap()).latency_ns


def run() -> list[Row]:
    db = SuccessRateDb(n_bitlines=512, n_groups=4, n_patterns=24)
    cm = CostModel()
    rows: list[Row] = []
    for mfr, m in (("M", 5), ("M", 7), ("H", 5), ("H", 7), ("H", 9)):
        prof = PROFILES[mfr]
        if m > prof.max_maj_fan_in:
            continue
        base = (cm.maj_op(3, 4, frac_supported=prof.frac_supported)
                .latency_ns / max(db.mean(mfr, 3, 4), 1e-3))

        def scen():
            out = {}
            n = 8
            while n <= prof.max_simul_rows:
                if n >= m:
                    sr = max(db.mean(mfr, m, n), 1e-3)
                    work = (m + 1) // 2  # AND fan-in work per op vs MAJ3's 2
                    for name, (use_sr, use_init) in {
                            "RealExp": (True, True), "RealInit": (False, True),
                            "RealSR": (True, False), "Ideal": (False, False),
                    }.items():
                        lat = scenario_latency(cm, m, n,
                                               prof.frac_supported, use_init)
                        eff = lat / (sr if use_sr else 1.0) / (work / 2)
                        out.setdefault(name, {})[n] = base / eff
                n <<= 1
            return out

        us, out = timed_us(scen, repeat=1)
        for name, per_n in out.items():
            desc = " ".join(f"N{n}:{v:.2f}x" for n, v in per_n.items())
            rows.append(row(f"fig18.maj{m}_{mfr}_{name}", us / 4, desc))
    return rows
