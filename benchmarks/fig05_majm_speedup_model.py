"""Fig 5: potential speedup of MAJ5/7/9 over MAJ3 under the paper's
equal-latency-per-op model ("All operation models assume equal latency
values based on the state-of-the-art MAJ3") across the 7 microbenchmarks."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.core.cost_model import CostModel

W = 32


def op_counts(maj_fan_in: int) -> dict[str, int]:
    """Pure op-count model (every MAJ op costs 1 unit)."""
    f = (maj_fan_in + 1) // 2              # AND/OR fan-in
    tree = CostModel.tree_nodes
    fa = 4 if maj_fan_in >= 5 else 6       # dual-rail full adder MAJ count
    add = W * fa
    return {
        "and": 2 * tree(2 * W, f),
        "or": 2 * tree(2 * W, f),
        "xor": 6 * (2 * W - 1),
        "add": add,
        "sub": add,
        "mul": W * W * 2 + (W - 1) * add,
        "div": W * ((W + 1) * fa + 3 * 2 * (W + 1) + 2),
    }


def run() -> list[Row]:
    def model():
        base = op_counts(3)
        return {m: {k: base[k] / op_counts(m)[k] for k in base}
                for m in (5, 7, 9)}

    us, sp = timed_us(model, repeat=1)
    rows: list[Row] = []
    for m, per in sp.items():
        logic = np.mean([per["and"], per["or"], per["xor"]])
        arith = np.mean([per["add"], per["sub"], per["mul"], per["div"]])
        rows.append(row(
            f"fig05.maj{m}_over_maj3", us / 3,
            f"logic={logic:.2f}x arith={arith:.2f}x "
            f"(paper MAJ9 logic avg 2.73x)"))
    return rows
