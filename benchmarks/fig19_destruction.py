"""Fig 19: cold-boot content destruction of one bank — PULSAR (Bulk-Write +
greedy Multi-RowInit cover, N=2..32) vs RowClone- and FracDRAM-based
baselines (paper: up to 20.87x / 7.55x; normalized to RowClone)."""

from __future__ import annotations

from benchmarks.common import Row, row, timed_us
from repro.core.cost_model import CostModel
from repro.core.destruction import (fracdram_destruction_cost,
                                    pulsar_destruction_cost,
                                    rowclone_destruction_cost)

ROWS_SA, N_SA = 512, 16  # paper-scale bank (H7 module)


def run() -> list[Row]:
    cm = CostModel(row_bits=65536)
    n_rows = ROWS_SA * N_SA

    def sweep():
        rc = rowclone_destruction_cost(cm, n_rows).latency_ns
        fr = fracdram_destruction_cost(cm, n_rows).latency_ns
        pul = {n: pulsar_destruction_cost(cm, ROWS_SA, N_SA, n).latency_ns
               for n in (2, 4, 8, 16, 32)}
        return rc, fr, pul

    us, (rc, fr, pul) = timed_us(sweep, repeat=1)
    rows = [row("fig19.rowclone_base", us / 7,
                f"{rc/1e6:.2f} ms/bank (1.00x)"),
            row("fig19.fracdram", us / 7,
                f"{fr/1e6:.2f} ms/bank ({rc/fr:.2f}x vs RowClone)")]
    for n, lat in pul.items():
        note = " paper:20.87x-vs-RC 7.55x-vs-Frac" if n == 32 else ""
        rows.append(row(f"fig19.pulsar_n{n}", us / 7,
                        f"{lat/1e6:.2f} ms/bank ({rc/lat:.2f}x vs RowClone, "
                        f"{fr/lat:.2f}x vs Frac){note}"))
    return rows
