"""Fig 3: FracDRAM (state-of-the-art baseline) MAJ3 success-rate
distribution across DDR4 modules — the paper's motivating measurement
(mean 78.85% on Mfr H DDR4; 19.37% below its DDR3 result)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.core.charact import SuccessRateDb

PAPER_MEAN = 0.7885


def run() -> list[Row]:
    db = SuccessRateDb(n_bitlines=1024, n_groups=6, n_patterns=32)

    def sweep():
        # 12 modules ~ 12 subarray positions across the bank (systematic PV).
        return [db.point("H", 3, 4, subarray_frac=(i + 0.5) / 12).mean
                for i in range(12)]

    us, rates = timed_us(sweep, repeat=1)
    mean = float(np.mean(rates))
    return [row("fig03.fracdram_maj3_ddr4_mean", us,
                f"sim={mean:.4f} paper={PAPER_MEAN} "
                f"min={min(rates):.3f} max={max(rates):.3f}")]
