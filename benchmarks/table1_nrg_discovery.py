"""Table 1: N_RG% — fraction of same-subarray (R_F, R_S) pairs that
simultaneously activate 2/4/8/16/32 rows, per manufacturer profile."""

from __future__ import annotations

from benchmarks.common import Row, row, timed_us
from repro.core.decoder import RowDecoder
from repro.core.geometry import DramGeometry
from repro.core.profiles import MFR_H, MFR_M, MFR_S

G9 = DramGeometry(row_bits=1024, rows_per_subarray=512, subarrays_per_bank=4,
                  banks=1)

PAPER = {  # H7-11 row of Table 1
    "H": {2: 0.0249, 4: 0.1263, 8: 0.3077, 16: 0.3533, 32: 0.0183},
    "M": {2: 0.0191, 4: 0.1292, 8: 0.3287, 16: 0.2083, 32: 0.0},
}


def run() -> list[Row]:
    rows: list[Row] = []
    for prof in (MFR_H, MFR_M, MFR_S):
        dec = RowDecoder.build(G9, prof, seed=11)

        def census():
            return dec.nrg_census(0, sample=4000, seed=3)

        us, c = timed_us(census, repeat=1)
        got = " ".join(f"{k}:{100*v:.1f}%" for k, v in c.items() if k > 1)
        paper = PAPER.get(prof.name)
        ref = (" paper " + " ".join(f"{k}:{100*v:.1f}%"
                                    for k, v in paper.items())
               if paper else " (no multi-row activation, as observed)")
        rows.append(row(f"table1.nrg_census_mfr{prof.name}", us,
                        f"sim {got or 'none'}{ref}"))
    return rows
