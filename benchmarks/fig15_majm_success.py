"""Fig 15: MAJ3/5/7/9 success rates vs N_RG (first demonstration of
reliable >3-input majority: paper MAJ5 73.93%, MAJ7 29.28% on Mfr H @32;
MAJ9+ omitted on M per its <1% observation)."""

from __future__ import annotations

from benchmarks.common import Row, row, timed_us
from repro.core.charact import SuccessRateDb
from repro.core.profiles import PROFILES

PAPER = {("H", 5, 32): 0.7393, ("H", 7, 32): 0.2928}


def run() -> list[Row]:
    db = SuccessRateDb(n_bitlines=1024, n_groups=6, n_patterns=32)
    rows: list[Row] = []
    for mfr in ("H", "M"):
        prof = PROFILES[mfr]
        for m in (3, 5, 7, 9):
            if m > prof.max_maj_fan_in:
                rows.append(row(f"fig15.maj{m}_{mfr}", 0.0,
                                "omitted (<1% success, as in paper)"))
                continue
            n = 4
            pts = {}
            while n <= prof.max_simul_rows:
                if n >= m:
                    us, pt = timed_us(
                        lambda mm=m, nn=n, f=mfr: db.point(f, mm, nn),
                        repeat=1)
                    pts[n] = pt.mean
                n <<= 1
            ref = {k[2]: v for k, v in PAPER.items()
                   if k[0] == mfr and k[1] == m}
            rows.append(row(
                f"fig15.maj{m}_{mfr}", us,
                "sim " + " ".join(f"N{k}:{v:.3f}" for k, v in pts.items())
                + (" paper " + " ".join(f"N{k}:{v}" for k, v in ref.items())
                   if ref else "")))
    return rows
