"""Fig 4: Monte-Carlo process-variation analysis of MAJ3 (the paper's SPICE
study): (a) success rate per input pattern vs variation, (b) bitline
deviation distribution vs variation (4-row activation, MAJ3(1,1,0))."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.core import analog
from repro.core.profiles import MFR_H

KEY = jax.random.PRNGKey(4)


def run() -> list[Row]:
    rows: list[Row] = []

    def patterns():
        out = {}
        for pv in (0.1, 0.2, 0.3, 0.4):
            # all-same patterns are always safe; mixed patterns degrade.
            dv_mixed = analog.deviation_distribution(
                KEY, MFR_H, m_inputs=3, copies=1, n_neutral=1, ones=2,
                process_variation=pv)
            dv_same = analog.deviation_distribution(
                KEY, MFR_H, m_inputs=3, copies=1, n_neutral=1, ones=3,
                process_variation=pv)
            out[pv] = (float(dv_mixed.mean()), float(dv_mixed.std()),
                       float(dv_same.mean()))
        return out

    us, res = timed_us(patterns, repeat=1)
    for pv, (mu, sd, mu_same) in res.items():
        rows.append(row(f"fig04.deviation_pv{int(pv*100)}", us / 4,
                        f"maj3(1,1,0) dV={mu*1e3:.1f}mV sd={sd*1e3:.2f}mV "
                        f"all-ones dV={mu_same*1e3:.1f}mV"))
    # Deviation drop vs single-row activation (paper: -41.14%).
    dv1 = analog.single_row_deviation(KEY, MFR_H, process_variation=0.2)
    dv3 = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                        n_neutral=1, ones=2,
                                        process_variation=0.2)
    drop = 1 - float(dv3.mean() / dv1.mean())
    rows.append(row("fig04.deviation_drop_vs_single", us,
                    f"sim={drop:.3f} paper=0.411"))
    return rows
