"""Fig 11: effect of input replication on bitline deviation (a) and MAJ3
success (b) for N in {4,8,16,32} across process-variation levels."""

from __future__ import annotations

import jax

from benchmarks.common import Row, row, timed_us
from repro.core import analog
from repro.core.profiles import MFR_H
from repro.core.replication import plan

KEY = jax.random.PRNGKey(11)


def run() -> list[Row]:
    rows: list[Row] = []
    base = None
    for n in (4, 8, 16, 32):
        rp = plan(3, n)

        def point():
            dv = analog.deviation_distribution(
                KEY, MFR_H, m_inputs=3, copies=rp.copies,
                n_neutral=rp.n_neutral, ones=2, process_variation=0.2)
            sr, _ = analog.maj_success_rate(
                KEY, MFR_H, m_inputs=3, copies=rp.copies,
                n_neutral=rp.n_neutral, n_bitlines=2048, n_patterns=32)
            return float(dv.mean()), sr

        us, (dv, sr) = timed_us(point, repeat=1)
        if n == 4:
            base = dv
        boost = dv / base - 1
        note = " paper:+159%" if n == 32 else ""
        rows.append(row(f"fig11.n{n}", us,
                        f"dV={dv*1e3:.1f}mV (+{100*boost:.0f}% vs N=4{note}) "
                        f"maj3_success={sr:.4f}"))
    return rows
