"""Benchmark aggregator: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig17] [--emit-dir DIR]

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
``--emit-dir`` additionally writes the gated modules' rows as
``BENCH_*.json`` baselines (see benchmarks/common.py for the schema;
``tools/bench_compare.py`` diffs a fresh emit against the committed
copies at the repo root).
"""

from __future__ import annotations

import argparse
import importlib
import os
import sys
import traceback

from benchmarks import common

# Modules with a recorded perf trajectory: their rows emit to these
# baseline files under --emit-dir (committed copies live at repo root).
BENCH_NAMES = {
    "kernel_bench": "BENCH_kernel.json",
    "bank_parallelism": "BENCH_bankpar.json",
    "reliability_sweep": "BENCH_reliability.json",
}

MODULES = [
    "fig03_fracdram_success",
    "fig04_process_variation",
    "fig05_majm_speedup_model",
    "table1_nrg_discovery",
    "fig11_input_replication",
    "fig14_maj3_success",
    "fig15_majm_success",
    "fig16_spatial_success",
    "fig17_microbenchmarks",
    "bank_parallelism",
    "fig18_nrg_sensitivity",
    "fig19_destruction",
    "fig20_realworld",
    "kernel_bench",
    "reliability_sweep",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--emit-dir", default=None, metavar="DIR",
                    help="write BENCH_*.json baselines for the gated "
                         "modules into DIR")
    args = ap.parse_args()
    if args.emit_dir:
        os.makedirs(args.emit_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
            for bname, us, derived in rows:
                print(f"{bname},{us},\"{derived}\"", flush=True)
            if args.emit_dir and name in BENCH_NAMES:
                path = common.emit_bench_json(
                    name, rows, os.path.join(args.emit_dir,
                                             BENCH_NAMES[name]))
                print(f"# wrote {path}", file=sys.stderr)
            else:
                common.drain_counters()  # never leak across modules
        except Exception:  # noqa: BLE001 — keep the suite running
            failed.append(name)
            common.drain_counters()
            print(f"{name},-1,\"FAILED: "
                  f"{traceback.format_exc().splitlines()[-1]}\"", flush=True)
    if failed:
        print(f"# {len(failed)} module(s) failed: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
