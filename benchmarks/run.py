"""Benchmark aggregator: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig17]

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = [
    "fig03_fracdram_success",
    "fig04_process_variation",
    "fig05_majm_speedup_model",
    "table1_nrg_discovery",
    "fig11_input_replication",
    "fig14_maj3_success",
    "fig15_majm_success",
    "fig16_spatial_success",
    "fig17_microbenchmarks",
    "bank_parallelism",
    "fig18_nrg_sensitivity",
    "fig19_destruction",
    "fig20_realworld",
    "kernel_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for bname, us, derived in mod.run():
                print(f"{bname},{us},\"{derived}\"", flush=True)
        except Exception:  # noqa: BLE001 — keep the suite running
            failed.append(name)
            print(f"{name},-1,\"FAILED: "
                  f"{traceback.format_exc().splitlines()[-1]}\"", flush=True)
    if failed:
        print(f"# {len(failed)} module(s) failed: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
