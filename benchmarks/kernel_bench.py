"""Kernel-layer microbenchmarks: wall time of the packed bit-plane ops on
this host (jnp oracle path — the CPU execution path; the Pallas TPU kernels
share the algorithm and are validated in interpret mode in tests).
Derived column reports effective Gbit/s over the bitline lanes.

Also benchmarks the engine dataplane end to end: a 16-op program through the
eager per-op path (Python dispatch + NumPy temporaries per op) vs the fused
lazy op-graph pipeline (one jit trace, transpose in/out once) — the §5.2
command-stream-economy argument applied to the host dataplane. Programs are
written against the public ``repro.pum`` operator frontend (`PumArray`)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.pum as pum
from benchmarks.common import Row, record_counters, row, timed_us
from repro.core import realworld
from repro.kernels import ref

W = 1 << 16  # packed words per plane = 2M bitlines


def _engine_prog16(dev, a, b, c):
    """16 PuM ops (the fused-pipeline staple): logicals + ripple
    adds/subs + popcount chained over three operands."""
    a = dev.asarray(a)
    t = a & b
    t = t ^ c
    t = t | b
    t = t + a
    t = t - c
    t = t ^ b
    t = t & a
    t = t + c
    t = t | a
    t = t - b
    t = t ^ a
    t = t & c
    t = t + b
    t = t.popcount()
    t = t + a
    t = t ^ c
    return t


def _bench_fused_vs_eager() -> list[Row]:
    rng = np.random.default_rng(7)
    n = 32 * W  # one full plane set: 2M elements = 2M bitlines
    a, b, c = (rng.integers(0, 2**32, n, dtype=np.uint64) for _ in range(3))

    eager = pum.device(width=32, fuse=False)
    fused = pum.device(width=32, fuse=True)

    def run_eager():
        return _engine_prog16(eager, a, b, c).to_numpy()

    def run_fused():
        return _engine_prog16(fused, a, b, c).to_numpy()

    want = run_eager()
    got = run_fused()  # warm-up: compiles the pipeline once
    ok = bool(np.array_equal(want, got)) and eager.stats == fused.stats

    # The full-plane runs are bandwidth-bound and noisy; extra repeats
    # let the best-of-N minimum converge so the BENCH perf gate is
    # stable run-to-run.
    us_e, _ = timed_us(run_eager, repeat=7)
    us_f, _ = timed_us(run_fused, repeat=7)
    # One traced run attaches the engine's flush/pipeline-cache counters
    # to the fused row in the BENCH baseline (tracing is out of the
    # timed loop, so the recorded wall time stays untraced).
    with pum.profile(fused):
        run_fused()
    record_counters("engine.fused_prog16", fused.counters)
    rows = [
        row("engine.eager_prog16", us_e,
            f"{16 * n / us_e:.0f} M ops*elem/s (per-op dispatch, "
            f"{n / 1e6:.0f}M lanes)"),
        row("engine.fused_prog16", us_f,
            f"{16 * n / us_f:.0f} M ops*elem/s ({us_e / us_f:.1f}x over "
            f"eager; bit_exact+stats_match={ok} — §Perf F0)"),
    ]
    return rows


def _engine_mulprog16(dev, a, b, c):
    """16 PuM ops centred on the fused mul/div/mod lowering (shift-add
    multiply, restoring division via the shared divmod tuple op) mixed
    with the cheaper ISA."""
    a = dev.asarray(a)
    t = a * b
    t = t + c
    t = t * a
    t = t - b
    t = t // c
    t = t ^ a
    t = t * c
    t = t | b
    t = t % a
    t = t + b
    t = t * t
    t = t & c
    t = t // b
    t = t + a
    t = t * b
    t = t ^ c
    return t


def _bench_fused_mul() -> list[Row]:
    """mul/div inside the fused flush (no eager fallback since PR 3)."""
    rng = np.random.default_rng(11)
    n = 32 * W
    width = 16
    a, b, c = (rng.integers(0, 1 << width, n, dtype=np.uint64)
               for _ in range(3))
    eager = pum.device(width=width, fuse=False)
    fused = pum.device(width=width, fuse=True)

    def run_eager():
        return _engine_mulprog16(eager, a, b, c).to_numpy()

    def run_fused():
        return _engine_mulprog16(fused, a, b, c).to_numpy()

    want, got = run_eager(), run_fused()  # warm-up compiles the pipeline
    ok = bool(np.array_equal(want, got)) and eager.stats == fused.stats
    # The full-plane runs are bandwidth-bound and noisy; extra repeats
    # let the best-of-N minimum converge so the BENCH perf gate is
    # stable run-to-run.
    us_e, _ = timed_us(run_eager, repeat=7)
    us_f, _ = timed_us(run_fused, repeat=7)
    return [
        row("engine.eager_mul16", us_e,
            f"{16 * n / us_e:.0f} M ops*elem/s (per-op dispatch, "
            f"width {width})"),
        row("engine.fused_mul16", us_f,
            f"{16 * n / us_f:.0f} M ops*elem/s ({us_e / us_f:.1f}x over "
            f"eager; bit_exact+stats_match={ok})"),
    ]


def _bench_fused_mul64() -> list[Row]:
    """Width-64 arithmetic through the fused pipeline: the 64-bit plane
    layout routes to the additively registered ``words-cpu-64``
    evaluator (NumPy word domain on CPU) — the program that used to be
    forced onto the per-op eager path."""
    rng = np.random.default_rng(17)
    n = 32 * W
    width = 64
    a, b, c = (rng.integers(0, 1 << 63, n, dtype=np.uint64)
               for _ in range(3))
    eager = pum.device(width=width, fuse=False)
    fused = pum.device(width=width, fuse=True)

    def run_eager():
        return _engine_mulprog16(eager, a, b, c).to_numpy()

    def run_fused():
        return _engine_mulprog16(fused, a, b, c).to_numpy()

    want, got = run_eager(), run_fused()  # warm-up builds the pipeline
    ok = bool(np.array_equal(want, got)) and eager.stats == fused.stats
    # The full-plane runs are bandwidth-bound and noisy; extra repeats
    # let the best-of-N minimum converge so the BENCH perf gate is
    # stable run-to-run.
    us_e, _ = timed_us(run_eager, repeat=7)
    us_f, _ = timed_us(run_fused, repeat=7)
    return [
        row("engine.eager_mul64", us_e,
            f"{16 * n / us_e:.0f} M ops*elem/s (per-op dispatch, "
            f"width {width})"),
        row("engine.fused_mul64", us_f,
            f"{16 * n / us_f:.0f} M ops*elem/s ({us_e / us_f:.1f}x over "
            f"eager; 64-bit plane layout via words-cpu-64 — the jitted "
            f"uint32-pair evaluator: carry-chained add/sub/mul and "
            f"Knuth-division divmod on lane pairs, one XLA trace; "
            f"bit_exact+stats_match={ok})"),
    ]


def _bench_sharded_prog16() -> list[Row]:
    """The 16-op staple through the ``shard-words`` fused backend: the
    program's word axis partitions across jax.devices() (one device on
    this host unless XLA forces more) — one flush, every device runs its
    slice of the same fused program."""
    import jax

    rng = np.random.default_rng(19)
    n = 32 * W
    a, b, c = (rng.integers(0, 2**32, n, dtype=np.uint64) for _ in range(3))
    eager = pum.device(width=32, fuse=False)
    sharded = pum.device(width=32, fuse=True,
                         fused_backend="shard-words")

    def run_eager():
        return _engine_prog16(eager, a, b, c).to_numpy()

    def run_sharded():
        return _engine_prog16(sharded, a, b, c).to_numpy()

    want, got = run_eager(), run_sharded()  # warm-up compiles per shard
    ok = bool(np.array_equal(want, got)) and eager.stats == sharded.stats
    us_s, _ = timed_us(run_sharded)
    return [
        row("engine.sharded_prog16", us_s,
            f"{16 * n / us_s:.0f} M ops*elem/s across "
            f"{len(jax.devices())} device(s) (shard-words word-axis "
            f"partition; bit_exact+stats_match={ok})"),
    ]


def _bench_async_flush() -> list[Row]:
    """Async flush: caller-thread cost of record + ``flush_async`` submit
    vs the full synchronous flush for the same 16-op program — the
    compile/dispatch/materialize pipeline runs on the worker, so the
    caller-visible latency is the off-thread win."""
    import time

    rng = np.random.default_rng(23)
    n = 32 * W
    a, b, c = (rng.integers(0, 2**32, n, dtype=np.uint64) for _ in range(3))
    dev = pum.device(width=32, fuse=True)
    ref_out = _engine_prog16(dev, a, b, c).to_numpy()  # warm-up compile

    def run_sync():
        out = _engine_prog16(dev, a, b, c)
        dev.flush()
        return out

    us_sync, out = timed_us(run_sync, repeat=7)
    ok = bool(np.array_equal(out.to_numpy(), ref_out))

    # Caller-side submit latency, one flush in flight at a time (drain
    # between repeats so the double-buffer semaphore never backpressures
    # the timed section).
    best = float("inf")
    for _ in range(7):
        t0 = time.perf_counter()
        out = _engine_prog16(dev, a, b, c)
        h = dev.flush_async()
        best = min(best, (time.perf_counter() - t0) * 1e6)
        h.result()
        ok = ok and bool(np.array_equal(out.to_numpy(), ref_out))
    us_submit = best
    with pum.profile(dev):
        _engine_prog16(dev, a, b, c)
        dev.flush_async().result()
    record_counters("engine.async_flush", dev.counters)
    dev.close()
    return [
        row("engine.async_flush", us_submit,
            f"caller submit {us_submit:.0f}us vs {us_sync:.0f}us sync "
            f"flush ({us_sync / max(us_submit, 1e-9):.1f}x of the flush "
            f"latency moved off the caller thread; bit_exact={ok})"),
    ]


def _engine_rawprog16(dev, a, b, c):
    """16 plane-wise ops on full uint64 bitmap words: out-of-width
    operands route through the raw packed-bitmap path, the workload the
    autotuner moves onto the unsplit 64-bit plane layout."""
    a = dev.asarray(a)
    t = a & b
    t = t ^ c
    t = t | b
    t = t & c
    t = t ^ a
    t = t | c
    t = t & b
    t = t ^ c
    t = t | a
    t = t & c
    t = t ^ b
    t = t | c
    t = t & a
    t = t ^ c
    t = t | b
    t = t ^ a
    return t


def _bench_autotuned() -> list[Row]:
    """Closed loop measure -> tune -> apply: profile the raw 16-op staple
    on the static width-32 default, let ``Device.autotune()`` pick a
    config from the measured counters (the raw workload rewards the
    unsplit 64-bit layout), and time the same program under the tuned
    plan. Bit-exactness and EngineStats identity are *asserted* — the
    plan may only move where/when the program runs."""
    rng = np.random.default_rng(29)
    n = 32 * W
    a, b, c = (rng.integers(0, 2**64, n, dtype=np.uint64) for _ in range(3))

    static = pum.device(width=32, fuse=True)
    tuned = pum.device(width=32, fuse=True)

    def run_static():
        return _engine_rawprog16(static, a, b, c).to_numpy()

    def run_tuned():
        return _engine_rawprog16(tuned, a, b, c).to_numpy()

    want = run_static()  # warm-up: compiles the static pipeline
    with pum.profile(tuned):
        run_tuned()  # priming run: populates the counters tuning reads
    plan = tuned.autotune(apply=True)
    knobs = plan.non_default(pum.EngineConfig(width=32, fuse=True))
    assert knobs, "autotune must select a non-default config here"
    static.reset_stats()  # compare one scored run per device
    tuned.reset_stats()
    want, got = run_static(), run_tuned()
    bit_exact = bool(np.array_equal(want, got))
    stats_match = static.stats == tuned.stats
    assert bit_exact and stats_match, (bit_exact, stats_match)

    us_s, _ = timed_us(run_static, repeat=7)
    us_t, _ = timed_us(run_tuned, repeat=7)
    with pum.profile(tuned):
        run_tuned()
    record_counters("engine.autotuned_prog16", tuned.counters)
    sel = ",".join(f"{k}={v}" for k, v in sorted(knobs.items()))
    return [
        row("engine.autotuned_prog16", us_t,
            f"{16 * n / us_t:.0f} M ops*elem/s under TunedPlan({sel}; "
            f"modeled {plan.baseline_score_s / plan.score_s:.1f}x)"),
        row("engine.autotuned_vs_static", us_s,
            f"static default {us_s:.0f}us vs tuned {us_t:.0f}us host "
            f"wall ({us_s / us_t:.2f}x; the plan minimizes the modeled "
            f"PuM cost — on this CPU host the words-cpu-64 raw path is "
            f"the same capability row as engine.fused_mul64; "
            f"bit_exact=True stats_match=True asserted — §Perf A0)"),
    ]


def _bench_leaf_cache() -> list[Row]:
    """The device-resident leaf cache, cold vs warm: the same raw 16-op
    program flushed repeatedly over the same three 2M-word bitmaps. Cold
    (``leaf_cache_bytes=0``) re-stages every operand's wire snapshot per
    flush; warm (default cache) hits on pointer+fingerprint and serves
    the device-resident buffers — the flush moves no leaf bytes at all.
    Outputs and EngineStats are asserted identical (the cache is an
    execution detail, never a semantics knob)."""
    rng = np.random.default_rng(31)
    n = 32 * W
    a, b, c = (rng.integers(0, 2**64, n, dtype=np.uint64) for _ in range(3))
    cold = pum.device(width=32, fuse=True, leaf_cache_bytes=0)
    warm = pum.device(width=32, fuse=True)

    def run_cold():
        return _engine_rawprog16(cold, a, b, c).to_numpy()

    def run_warm():
        return _engine_rawprog16(warm, a, b, c).to_numpy()

    want, got = run_cold(), run_warm()  # warm-up: compile + cache fill
    ok = bool(np.array_equal(want, got)) and cold.stats == warm.stats
    us_c, _ = timed_us(run_cold, repeat=7)
    us_w, _ = timed_us(run_warm, repeat=7)
    with pum.profile(warm):
        run_warm()
    record_counters("engine.leaf_cache_warm", warm.counters)
    mb = 3 * n * 8 / 1e6
    return [
        row("engine.leaf_cache_cold", us_c,
            f"leaf_cache_bytes=0: every flush re-stages ~{mb:.0f} MB of "
            f"leaf wire"),
        row("engine.leaf_cache_warm", us_w,
            f"{us_c / us_w:.2f}x vs cold (pointer+fingerprint hits serve "
            f"the device-resident leaf buffers, zero bytes staged; "
            f"bit_exact+stats_match={ok})"),
    ]


def _bench_app_kernels() -> list[Row]:
    """realworld packed-bitmap kernels at paper-scale operand sizes, eager
    vs fused routing (the raw planewise path): host wall time of the device
    path (the warm-up call verifies against direct NumPy once; the timed
    calls pass verify=False so the oracle is outside the timed region).
    BMI ANDs 30 x 2 MiB daily bitmaps; KCS star-extends 8192 6-cliques of
    a 2048-vertex graph through the bulk stacked-operand path — repeat
    calls reuse the memoized stacks, so fused flushes hit the leaf cache."""
    rng = np.random.default_rng(13)
    bitmaps = rng.integers(0, 2**64, (30, 1 << 18), dtype=np.uint64)
    n = 2048
    adj = np.triu((rng.random((n, n)) < 0.3).astype(np.uint8), 1)
    adj = adj + adj.T
    cliques = [tuple(cl) for cl in rng.integers(0, n, (8192, 6))]

    rows: list[Row] = []
    for name, fn, args in (
            ("bmi", realworld.bmi_active_users, (bitmaps,)),
            ("kclique", realworld.kclique_star, (adj, cliques))):
        eager = pum.device(width=32, fuse=False)
        fused = pum.device(width=32, fuse=True)
        fn(fused, *args)  # warm-up: verifies + compiles the fused pipeline
        fn(eager, *args)  # warm-up: verifies the eager path once too
        us_e, _ = timed_us(lambda: fn(eager, *args, verify=False))
        us_f, _ = timed_us(lambda: fn(fused, *args, verify=False))
        rows.append(row(f"app.{name}_eager", us_e, "per-op dispatch"))
        # The ratio is computed from the measured rows (never baked into
        # the string): bench_compare gates app.*_fused at >= 1.0x eager.
        rows.append(row(f"app.{name}_fused", us_f,
                        f"{us_e / us_f:.2f}x vs eager (raw planewise fused "
                        f"path: one jitted flush per call, leaf-cache hits "
                        f"serve the device-resident bitmap uploads with "
                        f"zero bytes staged)"))
    return rows


def run() -> list[Row]:
    rng = np.random.default_rng(0)
    rows: list[Row] = []

    x32 = jnp.asarray(rng.integers(0, 2**32, (31, W), dtype=np.uint64)
                      .astype(np.uint32).view(np.int32))
    fn = jax.jit(lambda a: ref.maj_n(a, 16))
    fn(x32).block_until_ready()
    us0, _ = timed_us(lambda: fn(x32).block_until_ready(), repeat=1)
    rows.append(row("kernel.maj31_oracle", us0,
                    f"{31*W*32/us0/1e3:.1f} Gbit/s (unpack-sum baseline)"))
    fn = jax.jit(lambda a: ref.maj_n_fast(a, 16))
    fn(x32).block_until_ready()
    us, _ = timed_us(lambda: fn(x32).block_until_ready())
    rows.append(row("kernel.maj31_bitsliced", us,
                    f"{31*W*32/us/1e3:.1f} Gbit/s ({us0/us:.0f}x over "
                    f"oracle — §Perf K0)"))

    a = jnp.asarray(rng.integers(0, 2**32, (32, W), dtype=np.uint64)
                    .astype(np.uint32).view(np.int32))
    b = jnp.asarray(rng.integers(0, 2**32, (32, W), dtype=np.uint64)
                    .astype(np.uint32).view(np.int32))
    fn = jax.jit(ref.bitserial_add)
    fn(a, b).block_until_ready()
    us, _ = timed_us(lambda: fn(a, b).block_until_ready())
    rows.append(row("kernel.bitserial_add32", us,
                    f"{W*32/us:.0f} M 32-bit adds/s"))

    fn = jax.jit(ref.bit_transpose32)
    fn(a).block_until_ready()
    us, _ = timed_us(lambda: fn(a).block_until_ready())
    rows.append(row("kernel.bit_transpose32", us,
                    f"{32*W*4/us/1e3:.1f} GB/s"))

    v = jnp.asarray(rng.choice([0.0, 1.2], (32, W)).astype(np.float32))
    c = jnp.asarray((20 + rng.standard_normal((32, W))).astype(np.float32))
    fn = jax.jit(lambda vv, cc: ref.charge_share(vv, cc, vdd=1.2, c_bl=116.0))
    fn(v, c).block_until_ready()
    us, _ = timed_us(lambda: fn(v, c).block_until_ready())
    rows.append(row("kernel.charge_share32", us,
                    f"{32*W*8/us/1e3:.1f} GB/s"))

    rows.extend(_bench_fused_vs_eager())
    rows.extend(_bench_fused_mul())
    rows.extend(_bench_fused_mul64())
    rows.extend(_bench_sharded_prog16())
    rows.extend(_bench_async_flush())
    rows.extend(_bench_autotuned())
    rows.extend(_bench_leaf_cache())
    rows.extend(_bench_app_kernels())
    return rows
