"""Kernel-layer microbenchmarks: wall time of the packed bit-plane ops on
this host (jnp oracle path — the CPU execution path; the Pallas TPU kernels
share the algorithm and are validated in interpret mode in tests).
Derived column reports effective Gbit/s over the bitline lanes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, row, timed_us
from repro.kernels import ref

W = 1 << 16  # packed words per plane = 2M bitlines


def run() -> list[Row]:
    rng = np.random.default_rng(0)
    rows: list[Row] = []

    x32 = jnp.asarray(rng.integers(0, 2**32, (31, W), dtype=np.uint64)
                      .astype(np.uint32).view(np.int32))
    fn = jax.jit(lambda a: ref.maj_n(a, 16))
    fn(x32).block_until_ready()
    us0, _ = timed_us(lambda: fn(x32).block_until_ready(), repeat=1)
    rows.append(row("kernel.maj31_oracle", us0,
                    f"{31*W*32/us0/1e3:.1f} Gbit/s (unpack-sum baseline)"))
    fn = jax.jit(lambda a: ref.maj_n_fast(a, 16))
    fn(x32).block_until_ready()
    us, _ = timed_us(lambda: fn(x32).block_until_ready())
    rows.append(row("kernel.maj31_bitsliced", us,
                    f"{31*W*32/us/1e3:.1f} Gbit/s ({us0/us:.0f}x over "
                    f"oracle — §Perf K0)"))

    a = jnp.asarray(rng.integers(0, 2**32, (32, W), dtype=np.uint64)
                    .astype(np.uint32).view(np.int32))
    b = jnp.asarray(rng.integers(0, 2**32, (32, W), dtype=np.uint64)
                    .astype(np.uint32).view(np.int32))
    fn = jax.jit(ref.bitserial_add)
    fn(a, b).block_until_ready()
    us, _ = timed_us(lambda: fn(a, b).block_until_ready())
    rows.append(row("kernel.bitserial_add32", us,
                    f"{W*32/us:.0f} M 32-bit adds/s"))

    fn = jax.jit(ref.bit_transpose32)
    fn(a).block_until_ready()
    us, _ = timed_us(lambda: fn(a).block_until_ready())
    rows.append(row("kernel.bit_transpose32", us,
                    f"{32*W*4/us/1e3:.1f} GB/s"))

    v = jnp.asarray(rng.choice([0.0, 1.2], (32, W)).astype(np.float32))
    c = jnp.asarray((20 + rng.standard_normal((32, W))).astype(np.float32))
    fn = jax.jit(lambda vv, cc: ref.charge_share(vv, cc, vdd=1.2, c_bl=116.0))
    fn(v, c).block_until_ready()
    us, _ = timed_us(lambda: fn(v, c).block_until_ready())
    rows.append(row("kernel.charge_share32", us,
                    f"{32*W*8/us/1e3:.1f} GB/s"))
    return rows
