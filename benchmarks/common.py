"""Shared benchmark plumbing: every benchmark module exposes
``run() -> list[(name, us_per_call, derived)]`` rows; run.py aggregates into
the required ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import time
from typing import Callable

Row = tuple[str, float, str]


def timed_us(fn: Callable, *args, repeat: int = 3, **kw) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best, out


def row(name: str, us: float, derived: str) -> Row:
    return (name, round(us, 2), derived)
