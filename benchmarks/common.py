"""Shared benchmark plumbing: every benchmark module exposes
``run() -> list[(name, us_per_call, derived)]`` rows; run.py aggregates into
the required ``name,us_per_call,derived`` CSV.

Recorded trajectory: run.py's ``--emit-dir`` writes the row set of the
gated modules as ``BENCH_*.json`` (schema below) so the repo carries a
committed perf baseline and ``tools/bench_compare.py`` can diff a fresh
run against it in CI. Benchmark modules attach telemetry counters to
individual rows via :func:`record_counters`; the emitter folds them in.

BENCH_*.json schema (``"schema": 1``)::

    {
      "schema": 1,
      "bench": "kernel_bench",            # source module
      "git_sha": "<12 hex>|unknown",
      "host": {"platform": ..., "machine": ..., "python": ...,
               "cpu_count": ...},
      "rows": {
        "<row name>": {
          "ns_per_call": <float>,          # best-of-repeat wall ns
          "derived": "<free-form metrics string>",
          "counters": {...}                # optional telemetry snapshot
        }, ...
      }
    }

``ns_per_call`` is host wall time (nanoseconds, explicit unit in the key);
model-domain latencies live inside ``derived``/``counters`` with their own
unit-suffixed names.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Callable

Row = tuple[str, float, str]

# Row-name -> counter snapshot, registered by benchmark modules while they
# run and folded into the next emit (cleared per module by run.py).
_COUNTERS: dict[str, dict] = {}


def timed_us(fn: Callable, *args, repeat: int = 3, **kw) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best, out


def row(name: str, us: float, derived: str) -> Row:
    return (name, round(us, 2), derived)


def record_counters(row_name: str, counters) -> None:
    """Attach a telemetry snapshot to ``row_name`` for the next BENCH
    emit. ``counters`` is a ``repro.telemetry.CounterBank`` (snapshotted
    via ``as_dict()``) or an already-plain dict."""
    _COUNTERS[row_name] = (counters.as_dict()
                           if hasattr(counters, "as_dict") else
                           dict(counters))


def drain_counters() -> dict[str, dict]:
    """Pop all registered row counters (run.py calls this per module so
    one module's counters never leak into another's emit)."""
    out = dict(_COUNTERS)
    _COUNTERS.clear()
    return out


def git_sha() -> str:
    """Short commit SHA of the working tree, ``"unknown"`` outside git."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def host_fingerprint() -> dict:
    """Coarse host identity stored with each baseline: bench_compare
    loosens thresholds when baseline and fresh run came from different
    hosts (wall-time rows are host-dependent)."""
    return {
        "platform": platform.system(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count() or 0,
    }


def emit_bench_json(bench: str, rows: list[Row], path: str,
                    counters: dict[str, dict] | None = None) -> str:
    """Write ``rows`` (plus any per-row ``counters``) as a BENCH_*.json
    baseline at ``path``; returns ``path``."""
    counters = drain_counters() if counters is None else counters
    doc = {
        "schema": 1,
        "bench": bench,
        "git_sha": git_sha(),
        "host": host_fingerprint(),
        "rows": {
            name: {
                "ns_per_call": us * 1e3,
                "derived": derived,
                **({"counters": counters[name]} if name in counters
                   else {}),
            }
            for name, us, derived in rows
        },
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
