#!/usr/bin/env python3
"""Docs link checker: fails on broken *relative* links in README.md and
docs/*.md.

Checks every ``[text](target)`` markdown link whose target is not an
absolute URL (``http(s)://``, ``mailto:``):

* the referenced file must exist (relative to the linking file);
* if the target carries a ``#anchor`` and points at a markdown file, the
  anchor must match a heading in that file (GitHub slug rules: lowercase,
  spaces -> dashes, punctuation dropped);
* bare ``#anchor`` targets are resolved against the linking file itself.

Usage: ``python tools/check_docs.py [root]`` (default: repo root inferred
from this file's location). Exits 1 listing every broken link.
"""

from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code ticks, lowercase,
    drop punctuation, spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def headings_of(path: pathlib.Path) -> set[str]:
    return {github_slug(h) for h in HEADING_RE.findall(
        path.read_text(encoding="utf-8"))}


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for target in LINK_RE.findall(text):
        if target.startswith(EXTERNAL):
            continue
        ref, _, anchor = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link -> {target} "
                          f"(missing {dest})")
            continue
        if anchor and dest.suffix == ".md":
            if github_slug(anchor) not in headings_of(dest):
                errors.append(f"{path}: broken anchor -> {target} "
                              f"(no heading '#{anchor}' in {dest.name})")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                        else pathlib.Path(__file__).resolve().parent.parent)
    files = [root / "README.md"] + sorted((root / "docs").glob("*.md"))
    errors: list[str] = []
    checked = 0
    for f in files:
        if f.exists():
            checked += 1
            errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {checked} files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
