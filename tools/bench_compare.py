#!/usr/bin/env python3
"""Perf-regression gate: diff a fresh BENCH_*.json against the committed
baseline.

Two modes:

* ``--check-rows`` — structural gate only: the fresh emit must contain
  exactly the baseline's row set (a renamed or dropped benchmark row is a
  structured error naming the rows, replacing CI's old silent
  grep-for-row-names pipeline).

Both modes additionally assert the fused-vs-eager invariant inside the
fresh emit: every ``app.<name>_fused`` row with an ``app.<name>_eager``
sibling must be at least as fast as the eager row (the fused pipeline
regressing below eager is exactly the data-movement bug the flush-path
leaf cache removed — this gate keeps it removed).
* full (default) — per-row relative wall-time comparison:
  ``fresh_ns / baseline_ns`` must stay below ``--threshold`` (default
  1.25, i.e. a >25% regression fails). When the two files carry
  different host fingerprints the threshold is multiplied by
  ``--host-grace`` (default 2.0): cross-host wall times gate only
  catastrophic regressions, same-host runs gate tightly.

Rows whose baseline or fresh time is non-positive (a FAILED row) are
errors in both modes. Speedups never fail — the gate is one-sided;
refresh the committed baseline to ratchet it.

Usage::

    PYTHONPATH=src python -m benchmarks.run --only kernel_bench \
        --emit-dir /tmp/bench > /dev/null
    python tools/bench_compare.py BENCH_kernel.json \
        /tmp/bench/BENCH_kernel.json [--threshold 1.25] [--check-rows]

Exit codes: 0 ok, 1 regression/row mismatch, 2 unusable input files.
The comparison logic is importable (``compare()``) for the unit tests.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_bench(path: str) -> dict:
    """Parse one BENCH_*.json; raises ValueError on schema mismatch."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != 1 or "rows" not in doc:
        raise ValueError(f"{path}: not a schema-1 BENCH file")
    return doc


def compare(baseline: dict, fresh: dict, threshold: float = 1.25,
            check_rows_only: bool = False,
            host_grace: float = 2.0) -> list[str]:
    """Return the list of failures (empty = gate passes).

    ``baseline``/``fresh`` are parsed BENCH documents. In row-check mode
    only the row sets are compared; in full mode each shared row's
    ``ns_per_call`` ratio is gated at ``threshold`` (× ``host_grace``
    when the host fingerprints differ).
    """
    b_rows, f_rows = baseline["rows"], fresh["rows"]
    failures = []
    missing = sorted(set(b_rows) - set(f_rows))
    extra = sorted(set(f_rows) - set(b_rows))
    if missing:
        failures.append(f"rows missing from fresh run: {missing}")
    if extra:
        failures.append(f"rows not in baseline (refresh it?): {extra}")
    # Fused-vs-eager invariant: for every app.<name>_fused row with an
    # app.<name>_eager sibling, the compiled path must not lose to eager
    # — the flush-path data-movement regression this repo already
    # shipped once. Checked within the fresh emit itself (both modes:
    # the structural gate is what CI runs on every push).
    for name in sorted(f_rows):
        if not (name.startswith("app.") and name.endswith("_fused")):
            continue
        eager = name[:-len("_fused")] + "_eager"
        if eager not in f_rows:
            continue
        fn = f_rows[name].get("ns_per_call", 0)
        en = f_rows[eager].get("ns_per_call", 0)
        if fn > 0 and en > 0 and fn > en:
            failures.append(
                f"{name}: fused path slower than eager sibling "
                f"({fn:.0f} ns vs {en:.0f} ns, {fn / en:.2f}x)")
    if check_rows_only:
        return failures

    limit = threshold
    if baseline.get("host") != fresh.get("host"):
        limit *= host_grace
    for name in sorted(set(b_rows) & set(f_rows)):
        b = b_rows[name].get("ns_per_call", 0)
        f = f_rows[name].get("ns_per_call", 0)
        if b <= 0 or f <= 0:
            failures.append(f"{name}: non-positive time "
                            f"(baseline {b} ns, fresh {f} ns)")
            continue
        ratio = f / b
        if ratio > limit:
            failures.append(
                f"{name}: {ratio:.2f}x slower than baseline "
                f"({b:.0f} ns -> {f:.0f} ns, limit {limit:.2f}x)")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate a fresh BENCH_*.json against the committed "
                    "baseline")
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("fresh", help="freshly emitted BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="max fresh/baseline per-row ratio (default 1.25)")
    ap.add_argument("--host-grace", type=float, default=2.0,
                    help="threshold multiplier when host fingerprints "
                         "differ (default 2.0)")
    ap.add_argument("--check-rows", action="store_true",
                    help="structural gate only: row sets must match")
    args = ap.parse_args(argv)

    try:
        baseline = load_bench(args.baseline)
        fresh = load_bench(args.fresh)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_compare: cannot load input: {e}", file=sys.stderr)
        return 2

    failures = compare(baseline, fresh, threshold=args.threshold,
                       check_rows_only=args.check_rows,
                       host_grace=args.host_grace)
    mode = "row set" if args.check_rows else "perf"
    if failures:
        print(f"bench_compare [{baseline['bench']}]: {mode} gate FAILED:",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"bench_compare [{baseline['bench']}]: {mode} gate OK "
          f"({len(baseline['rows'])} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
