#!/usr/bin/env python3
"""Public-API snapshot test for ``repro.pum``.

The ``repro.pum`` surface is the repo's one stable contract: this script
compares the *actual* exports (module ``__all__`` + the public attribute
surface of ``PumArray``/``Device``/``EngineConfig`` + the built-in backend
registrations) against the frozen snapshot below and exits 1 on any
drift — an accidentally-added export fails CI just like a removed one.

Intentional surface changes update ``EXPECTED`` here (run with
``--print`` to emit the current surface) and ``docs/api.md`` together.

Usage: ``PYTHONPATH=src python tools/check_api.py [--print]``
"""

from __future__ import annotations

import sys

# The frozen public surface. Dunders are part of the contract: PumArray's
# operator set IS the API.
EXPECTED = {
    "repro.pum": [
        "BackendSpec", "CapturedProgram", "CounterBank", "Device",
        "EngineConfig", "EngineStats", "FlushHandle", "LAYOUT32",
        "LAYOUT64", "PlaneLayout", "PumArray",
        "ReliabilityConfig", "ReliabilityMap", "Tracer",
        "TunedPlan", "Tuner", "WorkloadProfile",
        "as_device", "asarray", "available_backends", "calibrate",
        "default_device", "device", "get_backend", "get_layout", "profile",
        "register_backend", "select_backend", "unregister_backend",
    ],
    "PumArray": [
        "__add__", "__and__", "__array__", "__array_priority__",
        "__array_ufunc__", "__bool__", "__divmod__", "__eq__",
        "__floordiv__", "__ge__", "__getitem__", "__gt__", "__hash__",
        "__init__", "__le__", "__len__",
        "__lt__", "__mod__", "__mul__", "__ne__", "__or__", "__radd__",
        "__rand__", "__rdivmod__", "__repr__", "__rfloordiv__", "__rmod__",
        "__rmul__", "__ror__", "__rsub__", "__rxor__", "__sub__",
        "__xor__", "astype", "device", "dtype", "ndim", "popcount",
        "reduce_bits", "reshape", "shape", "size", "sum", "to_numpy",
    ],
    "Device": [
        "__enter__", "__exit__", "__init__", "__repr__", "asarray",
        "autotune", "calibrate", "capture", "charge", "client", "close",
        "counters", "flush", "flush_async", "latency_ms", "layout",
        "reliability", "reset_counters", "reset_stats", "stats", "width",
    ],
    "EngineConfig": [
        "backend", "banks", "chained", "cmd_buffer_lookahead",
        "controller", "donate_leaves",
        "flush_memory_bytes", "flush_threshold", "fuse", "fused_backend",
        "layout", "leaf_cache_bytes", "mfr",
        "ref_postponing", "reliability", "row_bits",
        "seed", "success_db", "use_pulsar", "width",
    ],
    # Built-in registrations (a superset is allowed: registering more
    # backends is the designed extension point).
    "backends": ["fast", "pallas-tpu", "pallas-tpu-64", "ref-vertical",
                 "ref-vertical-64", "shard-words", "sim", "words-cpu",
                 "words-cpu-64"],
}

_SKIP = {"__module__", "__qualname__", "__doc__", "__slots__", "__dict__",
         "__weakref__", "__dataclass_fields__", "__dataclass_params__",
         "__match_args__", "__annotations__", "__firstlineno__",
         "__static_attributes__", "__parameters__", "__orig_bases__",
         "__replace__"}


def _class_surface(cls) -> list[str]:
    """Names the class itself defines: public attributes plus dunders
    (the operator contract); single-underscore internals excluded."""
    return sorted(
        n for n in vars(cls)
        if n not in _SKIP
        and not (n.startswith("_") and not n.startswith("__")))


def actual_surface() -> dict[str, list[str]]:
    import repro.pum as pum

    missing = [n for n in pum.__all__ if not hasattr(pum, n)]
    if missing:
        raise AssertionError(f"__all__ names missing from module: {missing}")
    # Accidental exports: public module attributes beyond __all__
    # (submodules excluded — `import repro.pum.api` necessarily binds them).
    import types
    stray = sorted(
        n for n, v in vars(pum).items()
        if not n.startswith("_") and n not in pum.__all__
        and not isinstance(v, types.ModuleType))
    return {
        "repro.pum": sorted(pum.__all__) + [f"<stray:{n}>" for n in stray],
        "PumArray": _class_surface(pum.PumArray),
        "Device": _class_surface(pum.Device),
        "EngineConfig": sorted(
            f.name for f in
            __import__("dataclasses").fields(pum.EngineConfig)),
        "backends": sorted(pum.available_backends()),
    }


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    got = actual_surface()
    if "--print" in argv:
        import pprint
        pprint.pprint(got)
        return 0
    failures = []
    for key, want in EXPECTED.items():
        have = got[key]
        if key == "backends":
            lost = sorted(set(want) - set(have))
            if lost:
                failures.append(f"{key}: built-in backends missing: {lost}")
            continue
        if sorted(want) != have:
            extra = sorted(set(have) - set(want))
            lost = sorted(set(want) - set(have))
            failures.append(
                f"{key}: surface drift"
                + (f" — unexpected exports {extra}" if extra else "")
                + (f" — missing exports {lost}" if lost else ""))
    if failures:
        print("repro.pum public-API snapshot mismatch:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        print("(intentional? update tools/check_api.py EXPECTED and "
              "docs/api.md together; `--print` emits the current surface)",
              file=sys.stderr)
        return 1
    print(f"check_api: repro.pum surface OK "
          f"({sum(len(v) for v in got.values())} names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
